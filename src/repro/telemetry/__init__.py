"""End-to-end inference telemetry (DESIGN.md §telemetry).

Three layers, one rule — **observability must be data, not structure**:

* :mod:`repro.telemetry.trace` — host-side span/event recorder (bounded
  ring buffer, simulated- or wall-clock) with Chrome-trace/Perfetto
  export; instruments the request lifecycle queue admit → pack decision
  → dispatch → device step(s) → materialization → finish plus compile
  events.
* :mod:`repro.telemetry.taps` — on-device scalar taps threaded as extra
  **data** outputs through ``make_packed_step_fn`` (per-request eps
  norm, realized cache replay drift ``‖h_fresh − h_replay‖``, the
  kernel ledger's attention block counts). No host callbacks, no
  ``debug.print``, no recompiles: DCE of the tap outputs recovers the
  untapped jaxpr bit-for-bit (asserted in ``analysis/jaxpr_audit.py``).
* :mod:`repro.telemetry.export` — Prometheus text-format + JSON
  snapshot exporters over ``ServingMetrics`` summaries and tap
  aggregates (duck-typed: this module never imports the engine).

``Telemetry`` bundles a recorder + tap aggregator for the serving
engine; device values cross to the host only inside
``TapAggregator.aggregate()`` / trace export — never on the dispatch
path.
"""
from repro.telemetry.taps import TapAggregator, TapSample  # noqa: F401
from repro.telemetry.trace import SpanRecorder, TraceEvent  # noqa: F401


class Telemetry:
    """One serving session's telemetry bundle.

    ``taps=False`` keeps the engine on the untapped step family (spans
    only); ``taps=True`` routes dispatches through the tapped runners —
    same latents bit-for-bit, plus per-dispatch tap samples.

    ``profile=True`` adds the compiled-cost registry + per-request
    attribution ledger (DESIGN.md §profiling): the engine then measures
    dispatch wall-clock (one ``block_until_ready`` per dispatch —
    measurement overhead, latents and jaxprs unchanged) and splits it
    across requests with exact conservation. ``watchdog`` /
    ``postmortem_dir`` wire the SLO detector bank and crash flight
    recorder; passing only ``postmortem_dir`` builds a default-config
    watchdog.
    """

    def __init__(self, clock=None, taps: bool = False,
                 max_events: int = 65536, max_samples: int = 4096,
                 profile: bool = False, watchdog=None,
                 postmortem_dir=None):
        self.recorder = SpanRecorder(clock=clock, max_events=max_events)
        self.taps = TapAggregator(max_samples=max_samples)
        self.taps_enabled = bool(taps)
        self.profile = None
        self.attribution = None
        if profile:
            # lazy: profile.py imports jax + model costing; the plain
            # spans+taps bundle must stay importable without them
            from repro.telemetry.attribution import AttributionLedger
            from repro.telemetry.profile import CompiledCostRegistry
            self.profile = CompiledCostRegistry()
            self.attribution = AttributionLedger()
        if watchdog is None and postmortem_dir is not None:
            from repro.telemetry.watchdog import Watchdog
            watchdog = Watchdog()
        self.watchdog = watchdog
        if self.watchdog is not None:
            self.watchdog.recorder = self.recorder
            if postmortem_dir is not None:
                self.watchdog.postmortem_dir = postmortem_dir

    @property
    def profiling(self) -> bool:
        return self.profile is not None

    def bind_clock(self, clock) -> None:
        """Adopt the engine's clock (simulated or wall) if the recorder
        was built before the engine existed."""
        self.recorder.clock = clock

    def snapshot(self) -> dict:
        """JSON-friendly view: tap aggregates + recorder counters."""
        out = {"taps_enabled": self.taps_enabled,
               "tap_aggregates": self.taps.aggregate(),
               "events_recorded": self.recorder.events_recorded,
               "events_dropped": self.recorder.events_dropped,
               "span_occupancy": self.recorder.occupancy}
        if self.attribution is not None:
            out["attribution"] = self.attribution.snapshot()
        if self.watchdog is not None:
            out["alerts"] = [a.as_dict() for a in self.watchdog.alerts]
        return out
