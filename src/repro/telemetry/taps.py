"""On-device scalar taps (DESIGN.md §telemetry).

A *tap* is an extra **data** output of an already-compiled step — never
a host callback, never ``debug.print``, never structure. The tapped
step family computes, alongside its latents:

* ``eps_norm`` — per-request RMS of the post-guidance eps prediction
  (the solver's actual input; spikes mean the request's budget/cache
  combination is hurting it *now*);
* ``drift`` — the realized cache replay error. The cached forward
  already computes ``new_delta = where(refresh, h_deep − h_shallow,
  old_delta)``, so ``‖new_delta − old_delta‖ = ‖h_fresh − h_replay‖``
  exactly at refresh steps and exactly 0 at skip steps — the tap is
  FREE: a subtraction of two arrays the step already materializes
  (ROADMAP item 3's online refresh-threshold signal);
* ``attn_blocks`` — the kernel ledger's (active, total) score-tile
  counts for the dispatch layout (``PackLayout.attention_block_stats``),
  emitted through the same channel so a tap stream is self-describing.

The helpers below run INSIDE jit — jnp only, reductions to tiny [n]
vectors so the host transfer at export time is a few floats per
request-step. :class:`TapAggregator` holds samples as device arrays and
materializes them ONLY in :meth:`TapAggregator.aggregate` — dispatch
never blocks on a tap.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

#: keys a tapped step emits per group (drift only on the cached family)
TAP_NAMES = ("eps_norm", "finite", "drift", "attn_blocks")


def eps_norm_tap(eps: jnp.ndarray) -> jnp.ndarray:  # repro: traced
    """Per-request RMS of an eps batch [n, F, H, W, C] → [n]."""
    return jnp.sqrt(jnp.mean(jnp.square(eps),
                             axis=tuple(range(1, eps.ndim))))


def finite_tap(x: jnp.ndarray) -> jnp.ndarray:  # repro: traced
    """Per-request all-finite flag of a latent batch [n, ...] → [n] bool
    (the quarantine detector's in-graph signal: False means the row
    carries a NaN/Inf and the request must be re-run at full compute)."""
    return jnp.all(jnp.isfinite(x), axis=tuple(range(1, x.ndim)))


def drift_tap(new_delta: jnp.ndarray,
              old_delta: jnp.ndarray) -> jnp.ndarray:  # repro: traced
    """Per-request RMS replay drift ``‖h_fresh − h_replay‖`` from the
    deep-block residuals [n, mult, N, d] → [n] (0 at skip steps)."""
    d = new_delta - old_delta
    return jnp.sqrt(jnp.mean(jnp.square(d), axis=tuple(range(1, d.ndim))))


@dataclasses.dataclass
class TapSample:
    """One dispatch's tap outputs, still on device.

    ``eps_norm[g]`` is [k, n_g]; ``drift[g]`` is [k, n_g] (cached step
    family only); ``attn_blocks`` is [2] int32 (active, total) per
    micro-step. ``n_real[g]`` masks dummy tail slots out of aggregation.
    """
    time: float
    k: int
    groups: Tuple[Tuple[int, int], ...]      # ((mode, capacity), ...)
    n_real: Tuple[int, ...]                  # live requests per group
    eps_norm: Tuple[Any, ...]
    drift: Optional[Tuple[Any, ...]] = None
    attn_blocks: Optional[Any] = None
    finite: Optional[Tuple[Any, ...]] = None  # [k, n_g] bool per group


class TapAggregator:
    """Bounded window of :class:`TapSample` + lifetime scalars.

    Device arrays are held as-is until :meth:`aggregate` — the single
    host-sync point of the tap pipeline (export/summary time, off the
    dispatch path)."""

    def __init__(self, max_samples: int = 4096):
        self.samples: collections.deque = collections.deque(
            maxlen=max_samples)
        self.samples_recorded = 0

    def add(self, sample: TapSample) -> None:
        self.samples.append(sample)
        self.samples_recorded += 1

    def __len__(self) -> int:
        return len(self.samples)

    def aggregate(self) -> Dict[str, Any]:
        """Materialize the window into JSON-friendly aggregates — mean /
        max eps norm and replay drift over live request-steps, per-mode
        drift means (the online refresh-threshold signal), and the
        summed attention block ledger."""
        eps_all, drift_all = [], []
        per_mode: Dict[int, list] = {}
        blk_active = blk_total = 0
        n_request_steps = 0
        n_nonfinite = 0
        saw_finite = False
        for s in self.samples:
            for g, (mode, _cap) in enumerate(s.groups):
                n = s.n_real[g]
                if not n:
                    continue
                e = np.asarray(s.eps_norm[g])[:, :n].ravel()
                eps_all.append(e)
                n_request_steps += e.size
                if s.drift is not None:
                    d = np.asarray(s.drift[g])[:, :n].ravel()
                    drift_all.append(d)
                    per_mode.setdefault(mode, []).append(d)
                if s.finite is not None:
                    saw_finite = True
                    fi = np.asarray(s.finite[g])[:, :n]
                    n_nonfinite += int((~fi).sum())
            if s.attn_blocks is not None:
                a, t = (int(v) for v in np.asarray(s.attn_blocks))
                blk_active += a * s.k
                blk_total += t * s.k
        out: Dict[str, Any] = {
            "samples": len(self.samples),
            "samples_recorded": self.samples_recorded,
            "request_steps": n_request_steps,
        }
        if eps_all:
            e = np.concatenate(eps_all)
            out["eps_norm"] = {"mean": float(e.mean()),
                               "max": float(e.max())}
        if drift_all:
            d = np.concatenate(drift_all)
            out["drift"] = {"mean": float(d.mean()), "max": float(d.max()),
                            "p99": float(np.percentile(d, 99))}
            out["drift_per_mode"] = {
                str(m): float(np.concatenate(v).mean())
                for m, v in sorted(per_mode.items())}
        if blk_total:
            out["attn_blocks"] = {
                "active": blk_active, "total": blk_total,
                "skip_rate": 1.0 - blk_active / blk_total}
        if saw_finite:
            out["nonfinite_request_steps"] = n_nonfinite
        return out

    def counter_series(self):
        """Per-sample ``(time, {name: value})`` series for trace counter
        tracks — drift/eps means per dispatch, so the Perfetto timeline
        shows WHEN replay error spiked, not just that it did. Same sync
        discipline as :meth:`aggregate` (export time only)."""
        series = []
        for s in self.samples:
            eps_all, drift_all = [], []
            for g in range(len(s.groups)):
                n = s.n_real[g]
                if not n:
                    continue
                eps_all.append(np.asarray(s.eps_norm[g])[:, :n].ravel())
                if s.drift is not None:
                    drift_all.append(np.asarray(s.drift[g])[:, :n].ravel())
            if not eps_all:
                continue
            vals = {"eps_norm_mean": float(np.concatenate(eps_all).mean())}
            if drift_all:
                d = np.concatenate(drift_all)
                vals["drift_mean"] = float(d.mean())
                vals["drift_max"] = float(d.max())
            series.append((s.time, vals))
        return series
