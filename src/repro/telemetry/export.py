"""Metrics exporters (DESIGN.md §telemetry).

Renders ``ServingMetrics`` summaries (the engine's ``MetricsLedger``),
cache summaries, pipeline compile counters, and tap aggregates as:

* **Prometheus text format** (``prometheus_text``) — flat
  ``repro_<name>`` gauges with nested dicts flattened into label-free
  suffixed names (scrape endpoint / node-exporter textfile collector);
* **JSON snapshot** (``json_snapshot``) — one nested dict for dashboards
  and the bench artifacts;
* **structured log line** (``metrics_line``) — the ``--metrics-interval``
  one-liner: ``[metrics] k=v ...`` with stable key order.

Everything here is duck-typed over plain dicts — the engine imports
telemetry, so telemetry must never import the engine.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, Mapping, Optional


def _flatten(prefix: str, node: Any, out: Dict[str, float]) -> None:
    if isinstance(node, Mapping):
        for k, v in node.items():
            key = f"{prefix}_{k}" if prefix else str(k)
            _flatten(_sanitize(key), v, out)
        return
    if isinstance(node, bool):
        out[prefix] = float(node)
        return
    if isinstance(node, (int, float)):
        v = float(node)
        if not math.isnan(v):
            out[prefix] = v


def _sanitize(name: str) -> str:
    return "".join(c if c.isalnum() or c == "_" else "_" for c in name)


def flatten_metrics(snapshot: Mapping[str, Any],
                    prefix: str = "repro") -> Dict[str, float]:
    """Nested summary dicts → flat ``{metric_name: value}`` (non-numeric
    leaves and NaNs dropped — absent beats poisoned)."""
    out: Dict[str, float] = {}
    _flatten(_sanitize(prefix), snapshot, out)
    return out


def build_snapshot(summary: Optional[Mapping[str, Any]] = None,
                   cache: Optional[Mapping[str, Any]] = None,
                   compile_stats: Optional[Mapping[str, Any]] = None,
                   taps: Optional[Mapping[str, Any]] = None,
                   spans: Optional[Mapping[str, Any]] = None
                   ) -> Dict[str, Any]:
    """Assemble the canonical snapshot from the engine's pieces
    (``metrics.summary(wall)``, ``metrics.cache_summary()``,
    ``pipe.cache_stats()``, ``telemetry.taps.aggregate()``,
    ``recorder.counters()``)."""
    snap: Dict[str, Any] = {}
    if summary:
        snap["serving"] = dict(summary)
    if cache:
        snap["cache"] = dict(cache)
    if compile_stats:
        snap["compile"] = dict(compile_stats)
    if taps:
        snap["taps"] = dict(taps)
    if spans:
        snap["spans"] = dict(spans)
    return snap


def json_snapshot(summary: Optional[Mapping[str, Any]] = None,
                  cache: Optional[Mapping[str, Any]] = None,
                  compile_stats: Optional[Mapping[str, Any]] = None,
                  taps: Optional[Mapping[str, Any]] = None,
                  spans: Optional[Mapping[str, Any]] = None) -> str:
    return json.dumps(build_snapshot(summary, cache, compile_stats, taps,
                                     spans),
                      sort_keys=True)


def prometheus_text(summary: Optional[Mapping[str, Any]] = None,
                    cache: Optional[Mapping[str, Any]] = None,
                    compile_stats: Optional[Mapping[str, Any]] = None,
                    taps: Optional[Mapping[str, Any]] = None,
                    spans: Optional[Mapping[str, Any]] = None,
                    prefix: str = "repro") -> str:
    """Prometheus exposition text (type: gauge) for the snapshot."""
    flat = flatten_metrics(build_snapshot(summary, cache, compile_stats,
                                          taps, spans), prefix)
    lines = []
    for name in sorted(flat):
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {flat[name]:.10g}")
    return "\n".join(lines) + ("\n" if lines else "")


#: metrics_line key order — SLA signals first, then throughput, then
#: device-side health; anything else appends alphabetically
_LINE_ORDER = ("served", "p50", "p99", "deadline_hit_rate", "tokens_per_s",
               "packing_efficiency", "cache_hit_rate",
               "attn_block_skip_rate", "drift_mean", "drift_max",
               "eps_norm_mean", "compiled", "span_dropped",
               "span_occupancy")


def metrics_line(summary: Mapping[str, Any],
                 taps: Optional[Mapping[str, Any]] = None,
                 compile_stats: Optional[Mapping[str, Any]] = None,
                 spans: Optional[Mapping[str, Any]] = None,
                 tag: str = "metrics") -> str:
    """The periodic structured log line: ``[metrics] served=12 ...``."""
    flat: Dict[str, float] = {}
    _flatten("", dict(summary), flat)
    if taps:
        for k in ("drift", "eps_norm"):
            sub = taps.get(k)
            if isinstance(sub, Mapping):
                for stat in ("mean", "max"):
                    if stat in sub:
                        flat[f"{k}_{stat}"] = float(sub[stat])
    if compile_stats and "compiled" in compile_stats:
        flat["compiled"] = float(compile_stats["compiled"])
    if spans:
        if "events_dropped" in spans:
            flat["span_dropped"] = float(spans["events_dropped"])
        if "occupancy" in spans:
            flat["span_occupancy"] = float(spans["occupancy"])
    keys = [k for k in _LINE_ORDER if k in flat]
    keys += sorted(k for k in flat if k not in _LINE_ORDER)
    body = " ".join(f"{k}={flat[k]:.4g}" for k in keys)
    return f"[{tag}] {body}"
