"""SLO watchdog and crash flight recorder (DESIGN.md §profiling).

Rolling detectors over the engine's per-step observables:

* **recompile** — the jit ``compiled`` counter moved after warmup: the
  zero-recompile invariant broke in production, not in a test;
* **queue** — admission queue depth exceeded its limit (the controller
  is mispricing or traffic outran capacity);
* **p99** — rolling p99 of completed-request latency breached the SLO;
* **drift** — cache replay drift (the taps' ``‖h_fresh − h_replay‖``)
  spiked past the configured limit.

Each firing emits a structured ``alert.<kind>`` instant event into the
:class:`~repro.telemetry.trace.SpanRecorder` (so alerts land in the
same Chrome trace as the spans they explain) and, when a post-mortem
directory is configured, dumps a flight-recorder bundle: last-N spans,
engine/cache/queue snapshot, in-flight request states, attribution
totals, and the compiled-cost registry. The same ``dump()`` path runs
on an uncaught engine exception, so a crash leaves evidence.

Detectors are host-only arithmetic over numbers the engine already
materialized — the watchdog never forces a device sync (the taps'
``aggregate()`` remains the only host-sync point, at its existing
cadence). Per-kind cooldowns and a max-dump cap keep a persistent
breach from flooding the disk.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence

from repro.telemetry.trace import SpanRecorder

ALERT_RECOMPILE = "recompile"
ALERT_QUEUE = "queue"
ALERT_P99 = "p99"
ALERT_DRIFT = "drift"
ALERT_NONFINITE = "nonfinite"


@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    p99_slo_s: Optional[float] = None   # None disables the p99 detector
    queue_limit: int = 256
    drift_limit: float = 1e-2
    warmup_steps: int = 8               # ignore recompiles before this
    taps_every: int = 16                # engine steps between tap drift
    #                                     checks (each is one host sync)
    window: int = 64                    # latency window for rolling p99
    min_latencies: int = 8              # need this many before p99 fires
    cooldown_steps: int = 50            # per-kind re-fire suppression
    max_dumps: int = 4


@dataclasses.dataclass
class Alert:
    kind: str
    step: int
    time: float
    value: float
    limit: float
    detail: str = ""

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _p99(sorted_vals: Sequence[float]) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(0.99 * len(sorted_vals)))
    return sorted_vals[idx]


class Watchdog:
    """Per-step detector bank + post-mortem dumper. ``recorder`` and
    ``postmortem_dir`` are bound by :class:`~repro.telemetry.Telemetry`."""

    def __init__(self, config: Optional[WatchdogConfig] = None,
                 recorder: Optional[SpanRecorder] = None,
                 postmortem_dir: Optional[str] = None):
        self.config = config or WatchdogConfig()
        self.recorder = recorder
        self.postmortem_dir = postmortem_dir
        self.alerts: List[Alert] = []
        self.dumps_written: List[str] = []
        self._step = 0
        self._compiled_baseline: Optional[int] = None
        self._last_fire: Dict[str, int] = {}
        self._pending_dump = False
        self._nonfinite_seen = 0

    # -- detection ------------------------------------------------------

    def _fire(self, kind: str, now: float, value: float, limit: float,
              detail: str) -> Optional[Alert]:
        last = self._last_fire.get(kind)
        if last is not None and self._step - last < self.config.cooldown_steps:
            return None
        self._last_fire[kind] = self._step
        alert = Alert(kind=kind, step=self._step, time=now, value=value,
                      limit=limit, detail=detail)
        self.alerts.append(alert)
        self._pending_dump = True
        if self.recorder is not None:
            self.recorder.instant(f"alert.{kind}", args=alert.as_dict())
        return alert

    def observe_step(self, *, now: float, queued: int, inflight: int,
                     compiled: int,
                     latencies: Sequence[float] = (),
                     drift_max: Optional[float] = None,
                     nonfinite: int = 0) -> List[Alert]:
        """Run all detectors against one engine step's observables.
        Returns the alerts that fired (already recorded as events)."""
        self._step += 1
        cfg = self.config
        fired: List[Alert] = []

        if self._step <= cfg.warmup_steps or self._compiled_baseline is None:
            self._compiled_baseline = compiled
        elif compiled > self._compiled_baseline:
            a = self._fire(ALERT_RECOMPILE, now, float(compiled),
                           float(self._compiled_baseline),
                           f"jit compile counter {self._compiled_baseline}"
                           f" -> {compiled} after warmup")
            self._compiled_baseline = compiled
            if a:
                fired.append(a)

        if queued > cfg.queue_limit:
            a = self._fire(ALERT_QUEUE, now, float(queued),
                           float(cfg.queue_limit),
                           f"{queued} queued / {inflight} in flight")
            if a:
                fired.append(a)

        if cfg.p99_slo_s is not None and len(latencies) >= cfg.min_latencies:
            recent = sorted(list(latencies)[-cfg.window:])
            p99 = _p99(recent)
            if p99 > cfg.p99_slo_s:
                a = self._fire(ALERT_P99, now, p99, cfg.p99_slo_s,
                               f"rolling p99 over last {len(recent)}"
                               " completions")
                if a:
                    fired.append(a)

        if drift_max is not None and drift_max > cfg.drift_limit:
            a = self._fire(ALERT_DRIFT, now, float(drift_max),
                           cfg.drift_limit, "cache replay drift spike")
            if a:
                fired.append(a)

        # nonfinite is the engine's lifetime quarantine count: any growth
        # means NaN/Inf latents were detected and recovery (weak→powerful
        # re-enqueue) engaged — alert so the recovery action is visible in
        # the same trace. The seen-mark only advances on an actual fire,
        # so growth suppressed by the cooldown re-fires once it expires.
        if nonfinite > self._nonfinite_seen:
            a = self._fire(ALERT_NONFINITE, now, float(nonfinite),
                           float(self._nonfinite_seen),
                           "non-finite latents quarantined; escalated to"
                           " full compute")
            if a:
                fired.append(a)
                self._nonfinite_seen = nonfinite
        return fired

    def should_dump(self) -> bool:
        return (self._pending_dump and self.postmortem_dir is not None
                and len(self.dumps_written) < self.config.max_dumps)

    # -- the flight recorder -------------------------------------------

    def dump(self, *, reason: str,
             engine_snapshot: Optional[Dict[str, Any]] = None,
             attribution: Optional[Any] = None,
             registry: Optional[Any] = None,
             taps: Optional[Dict[str, Any]] = None,
             last_spans: int = 512) -> Optional[str]:
        """Write one post-mortem bundle to ``postmortem_dir``. Never
        raises (a broken dumper must not mask the original failure);
        returns the path, or None when disabled/capped/failed."""
        self._pending_dump = False
        if (self.postmortem_dir is None
                or len(self.dumps_written) >= self.config.max_dumps):
            return None
        try:
            bundle: Dict[str, Any] = {
                "reason": reason,
                "step": self._step,
                "alerts": [a.as_dict() for a in self.alerts],
                "engine": engine_snapshot or {},
            }
            if self.recorder is not None:
                bundle["spans"] = [
                    dataclasses.asdict(e)
                    for e in list(self.recorder.events)[-last_spans:]]
                bundle["span_counters"] = self.recorder.counters()
            if attribution is not None:
                bundle["attribution"] = attribution.snapshot()
            if registry is not None:
                bundle["compiled_costs"] = registry.reconcile()
            if taps:
                bundle["taps"] = taps
            os.makedirs(self.postmortem_dir, exist_ok=True)
            path = os.path.join(
                self.postmortem_dir,
                f"postmortem_{len(self.dumps_written)}.json")
            with open(path, "w") as f:
                json.dump(bundle, f, indent=1, default=str)
            self.dumps_written.append(path)
            return path
        except Exception:                         # noqa: BLE001
            return None
