"""Span/event recorder with Chrome-trace export (DESIGN.md §telemetry).

Host-side only: records what the *engine* does (admit, pack, dispatch,
materialize, retire, compile), never what the device computes — device
observability is :mod:`repro.telemetry.taps`. The buffer is a bounded
ring (``collections.deque(maxlen=...)``): an engine serving indefinitely
must not grow memory per dispatch; drops are counted, not silent.

Timestamps come from an injected ``clock()`` — the serving engine's
simulated clock in tests (deterministic traces) or ``time.monotonic``
in production. Export renders the buffer as Chrome trace-event JSON
(``{"traceEvents": [...]}``) loadable in Perfetto / ``chrome://tracing``:
complete events (``ph="X"``) for spans, instants (``ph="i"``) for
events, counters (``ph="C"``) for gauges. Request lifecycles render as
one row per request (``tid`` = request id) under the "requests" track;
engine activity renders under ``tid=0``.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional

#: trace rows: engine-wide activity vs per-request lifecycle tracks
ENGINE_PID = 1
REQUEST_PID = 2


@dataclasses.dataclass
class TraceEvent:
    name: str
    ph: str                      # 'X' complete | 'i' instant | 'C' counter
    ts: float                    # seconds (exported as µs)
    dur: float = 0.0             # seconds, complete events only
    pid: int = ENGINE_PID
    tid: int = 0
    args: Optional[Dict[str, Any]] = None

    def to_chrome(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "name": self.name, "ph": self.ph, "pid": self.pid,
            "tid": self.tid, "ts": self.ts * 1e6,
        }
        if self.ph == "X":
            out["dur"] = max(self.dur, 0.0) * 1e6
        if self.ph == "i":
            out["s"] = "t"       # thread-scoped instant
        if self.args:
            out["args"] = self.args
        return out


class SpanRecorder:
    """Bounded ring buffer of :class:`TraceEvent`.

    >>> rec = SpanRecorder(clock=engine.clock)
    >>> with rec.span("dispatch", args={"k": 4}):
    ...     run()
    >>> rec.dump("trace.json")          # open in ui.perfetto.dev
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 max_events: int = 65536):
        self.clock = clock or time.monotonic
        self.events: collections.deque = collections.deque(maxlen=max_events)
        self.events_recorded = 0
        self.events_dropped = 0

    # -- recording -----------------------------------------------------

    def _push(self, ev: TraceEvent) -> None:
        if len(self.events) == self.events.maxlen:
            self.events_dropped += 1
        self.events.append(ev)
        self.events_recorded += 1

    @contextmanager
    def span(self, name: str, tid: int = 0,
             args: Optional[Dict[str, Any]] = None):
        """Time a with-block as a complete event."""
        t0 = self.clock()
        try:
            yield
        finally:
            self._push(TraceEvent(name, "X", t0, self.clock() - t0,
                                  tid=tid, args=args))

    def complete(self, name: str, start: float, end: float, *,
                 pid: int = ENGINE_PID, tid: int = 0,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """A span whose endpoints were stamped elsewhere (request
        lifecycles: admit/finish stamps come from the engine)."""
        self._push(TraceEvent(name, "X", start, end - start,
                              pid=pid, tid=tid, args=args))

    def instant(self, name: str, tid: int = 0,
                args: Optional[Dict[str, Any]] = None) -> None:
        self._push(TraceEvent(name, "i", self.clock(), tid=tid, args=args))

    def counter(self, name: str, values: Dict[str, float],
                ts: Optional[float] = None) -> None:
        """Gauge sample; ``ts`` backdates it (tap values are synced at
        export time but belong at their dispatch timestamp)."""
        self._push(TraceEvent(name, "C",
                              self.clock() if ts is None else ts,
                              args=dict(values)))

    @property
    def occupancy(self) -> float:
        """Ring-buffer fill fraction in [0, 1] — 1.0 means the next
        event evicts the oldest (drops are already being counted)."""
        cap = self.events.maxlen or 1
        return len(self.events) / cap

    def counters(self) -> Dict[str, float]:
        """Exporter-facing health counters (satellite: silent span loss
        must be observable in Prometheus/metrics_line)."""
        return {
            "events_recorded": float(self.events_recorded),
            "events_dropped": float(self.events_dropped),
            "occupancy": self.occupancy,
            "capacity": float(self.events.maxlen or 0),
        }

    # -- export --------------------------------------------------------

    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object (Perfetto-loadable)."""
        meta = [
            {"name": "process_name", "ph": "M", "pid": ENGINE_PID,
             "args": {"name": "engine"}},
            {"name": "process_name", "ph": "M", "pid": REQUEST_PID,
             "args": {"name": "requests"}},
        ]
        return {"traceEvents": meta + [e.to_chrome() for e in self.events],
                "displayTimeUnit": "ms"}

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)

    def by_name(self, name: str) -> List[TraceEvent]:
        return [e for e in self.events if e.name == name]
