"""Compiled-cost registry (DESIGN.md §profiling).

The analytic FLOPs ledger (``core.scheduler`` / ``core.packing`` /
``cache.ledger``) prices every budget decision the serving stack makes —
but nothing in PR 1–7 verified that what XLA *compiles* agrees with the
arithmetic. This module closes the loop: it harvests
``Compiled.cost_analysis()`` / ``memory_analysis()`` from every
executable in :class:`~repro.pipeline.pipeline.FlexiPipeline`'s runner
caches — via the jax AOT path (``jitted.lower(*specs).compile()``),
which never touches the jit dispatch cache, so harvesting provably adds
**zero recompiles** (``cache_stats()['compiled']`` is flat across a
harvest) — and reconciles three numbers per step family:

* **analytic** — the ledger's count of useful work (block-sparse
  attention priced at the tiles the kernel visits, cache-skip steps at
  shallow blocks only);
* **XLA** — what the compiled HLO claims it computes. Caveats the
  report carries explicitly: on CPU the HLO cost model counts a
  ``while``/``scan`` body ONCE (trip-count-blind — a ``k_steps=8``
  runner reports one micro-step of flops) and a ``lax.cond`` at roughly
  one branch, so the registry reconciles XLA against the analytic
  **body** cost (one micro-step, refresh-upper bound for the cached
  family), never the per-dispatch total;
* **wall** — measured dispatch wall-clock (EWMA + min), fed by the
  serving engine when profiling is on. Wall is the only number that
  sees trip count, fusion, and memory traffic for real; the
  per-dispatch analytic total over wall is the achieved-FLOPs/s the
  roofline table reports.

Packed-runner argument specs are **derived from the cache key alone**
(`packed_arg_specs`) — the same ``("packed", layout, solver, ...)``
tuples the zero-recompile invariant keys on — so the engine's whole
warm set is harvestable without ever having seen a real argument.
Non-packed runners (static / cached / flow sample paths) record their
spec + per-call analytic cost at first dispatch when
``FlexiPipeline.enable_cost_profiling()`` is on.

``packed_key(...)`` mirrors ``FlexiPipeline.packed_step``'s key tuple;
``tests/test_profile.py`` asserts the mirror matches the runner cache
for every layout the engine actually dispatched, so drift between the
two fails loudly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.cache import ledger as cache_ledger
from repro.configs.base import ModelConfig
from repro.core.scheduler import dit_block_flops
from repro.models import dit as dit_mod
from repro.models.common import dtype_of

#: reconciliation flag ids (the drift report's vocabulary)
FLAG_COMPILED_DENSE = "compiled-dense"
FLAG_NO_XLA_FLOPS = "xla-flops-missing"
FLAG_XLA_DRIFT = "xla-analytic-drift"

#: |log(xla/analytic)| beyond this raises the drift flag (XLA counts
#: softmax/normalization transcendentals the ledger rounds away, so the
#: bound is loose by design)
DRIFT_LOG_RATIO = 2.3                     # ~10x either way


def packed_key(layout: Any, *, solver: str = "ddim",
               guidance_scale: float = 1.5, clip_x0: float = 0.0,
               k_steps: int = 1, cache_split: Optional[int] = None,
               attn_backend: str = "auto", taps: bool = False) -> Tuple:
    """Mirror of ``FlexiPipeline.packed_step``'s cache-key tuple. The
    registry and the engine's wall observations key on this; the mirror
    is pinned against the real cache by ``tests/test_profile.py``."""
    return ("packed", layout, solver, guidance_scale, clip_x0, k_steps,
            cache_split, attn_backend, taps)


def packed_arg_specs(cfg: ModelConfig, key: Tuple,
                     params: Any) -> Tuple:
    """ShapeDtypeStruct argument tree of the packed runner at ``key``,
    derived purely from the key + config — the same construction
    ``ServingEngine`` uses for real dispatches (and its dummy warmup
    dispatches), so ``runner.lower(*specs)`` reproduces the exact
    compiled signature."""
    (_tag, layout, _solver, _gs, _clip, k, split, _backend, _taps) = key
    sds = jax.ShapeDtypeStruct
    param_specs = jax.tree_util.tree_map(
        lambda a: sds(jnp.shape(a), a.dtype), params)
    mult = 2 if layout.guided else 1
    delta_dtype = dtype_of(cfg.compute_dtype)
    xs, metas, keys, deltas, refreshes = [], [], [], [], []
    for mode, cap in layout.groups:
        xs.append(sds((cap,) + cfg.dit.latent_shape, jnp.float32))
        metas.append(sds((k, 3, cap), jnp.int32))
        keys.append(sds((k, cap, 2), jnp.uint32))
        if split is not None:
            deltas.append(sds((cap, mult, dit_mod.tokens_for_mode(cfg, mode),
                               cfg.d_model), delta_dtype))
            refreshes.append(sds((k, cap), jnp.bool_))
    args: Tuple = (param_specs, tuple(xs), tuple(metas), tuple(keys))
    if split is not None:
        args += (tuple(deltas), tuple(refreshes))
    return args


def packed_analytic(cfg: ModelConfig, key: Tuple) -> Dict[str, float]:
    """Analytic ledger numbers for the packed executable at ``key``:
    ``body`` (one micro-step of the whole padded pack, dummy slots
    included — what the hardware computes), ``dense_body`` (same work
    priced at the dense-attention convention, the compiled-dense
    sentinel), ``deep_body`` (the deep-block share a cached all-skip
    micro-step avoids), and the per-dispatch totals."""
    layout, k, split, backend = key[1], key[5], key[6], key[7]
    body = layout.cost(cfg, attn_backend=backend).flops
    dense = layout.cost(cfg, attn_backend="dense").flops
    deep = 0.0
    if split is not None:
        rows = layout.cost(cfg, attn_backend=backend).rows
        C = layout.resolve_capacity(cfg)
        deep = (rows * dit_block_flops(cfg, C, attn_backend=backend)
                * (cfg.num_layers - split) / cfg.num_layers)
    return {"body": float(body), "dense_body": float(dense),
            "deep_body": float(deep), "dispatch": float(k * body),
            "dispatch_skip": float(k * (body - deep))}


@dataclasses.dataclass
class CompiledCost:
    """One executable's reconciled record."""
    key: Tuple
    family: str                      # packed | packed-cached | static | ...
    label: str
    analytic_body: float             # one body invocation (upper bound)
    analytic_body_skip: float        # cached all-skip lower bound
    analytic_dense_body: float       # dense-attention convention
    analytic_dispatch: float         # per runner call (x k micro-steps)
    xla_flops: Optional[float] = None
    xla_bytes: Optional[float] = None
    xla_transcendentals: Optional[float] = None
    arg_bytes: Optional[int] = None
    out_bytes: Optional[int] = None
    temp_bytes: Optional[int] = None
    code_bytes: Optional[int] = None
    error: Optional[str] = None

    @property
    def xla_over_analytic(self) -> Optional[float]:
        if not self.xla_flops or self.analytic_body <= 0:
            return None
        return self.xla_flops / self.analytic_body


@dataclasses.dataclass
class WallStats:
    ewma_s: float
    min_s: float
    n: int
    total_s: float


class CompiledCostRegistry:
    """Harvests, stores, and reconciles compiled-cost records, keyed by
    the SAME tuples ``FlexiPipeline``'s runner cache uses."""

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self.records: Dict[Tuple, CompiledCost] = {}
        self.walls: Dict[Tuple, WallStats] = {}

    # -- wall observations (fed per dispatch by the engine) -------------

    def observe_wall(self, key: Tuple, wall_s: float) -> None:
        if wall_s <= 0:
            return
        w = self.walls.get(key)
        if w is None:
            self.walls[key] = WallStats(wall_s, wall_s, 1, wall_s)
        else:
            w.ewma_s = (1 - self.alpha) * w.ewma_s + self.alpha * wall_s
            w.min_s = min(w.min_s, wall_s)
            w.n += 1
            w.total_s += wall_s

    # -- harvest --------------------------------------------------------

    def harvest(self, pipe: Any) -> Dict[str, int]:
        """AOT-compile-and-inspect every runner in ``pipe``'s cache.
        Never touches the jit dispatch cache (``cache_stats()`` stays
        flat); failures degrade to per-record ``error`` strings — XLA
        backends differ in what ``cost_analysis`` exposes."""
        harvested = errors = skipped = 0
        recorded = getattr(pipe, "profile_specs", None) or {}
        for key, fn in pipe.runners().items():
            if key in self.records and self.records[key].error is None:
                continue
            if key[0] == "packed":
                specs = packed_arg_specs(pipe.cfg, key, pipe.params)
                an = packed_analytic(pipe.cfg, key)
                rec = CompiledCost(
                    key=key,
                    family="packed-cached" if key[6] is not None
                    else "packed",
                    label=(f"packed{'+cache' if key[6] is not None else ''}"
                           f" k={key[5]} groups={key[1].groups}"
                           f" attn={key[7]} taps={key[8]}"),
                    analytic_body=an["body"],
                    analytic_body_skip=an["body"] - an["deep_body"],
                    analytic_dense_body=an["dense_body"],
                    analytic_dispatch=an["dispatch"])
            elif key in recorded:
                specs, analytic = recorded[key]
                rec = CompiledCost(
                    key=key, family=str(key[0]),
                    label=f"{key[0]} sample runner",
                    analytic_body=float(analytic),
                    analytic_body_skip=float(analytic),
                    analytic_dense_body=float(analytic),
                    analytic_dispatch=float(analytic))
            else:
                skipped += 1          # sample-path runner dispatched
                continue              # before profiling was enabled
            try:
                compiled = fn.lower(*specs).compile()
                ca = compiled.cost_analysis()
                if isinstance(ca, (list, tuple)):
                    ca = ca[0] if ca else {}
                if ca:
                    rec.xla_flops = float(ca.get("flops", 0.0)) or None
                    rec.xla_bytes = (float(ca.get("bytes accessed", 0.0))
                                     or None)
                    rec.xla_transcendentals = float(
                        ca.get("transcendentals", 0.0)) or None
                ma = compiled.memory_analysis()
                if ma is not None:
                    rec.arg_bytes = getattr(ma, "argument_size_in_bytes",
                                            None)
                    rec.out_bytes = getattr(ma, "output_size_in_bytes",
                                            None)
                    rec.temp_bytes = getattr(ma, "temp_size_in_bytes", None)
                    rec.code_bytes = getattr(ma,
                                             "generated_code_size_in_bytes",
                                             None)
                harvested += 1
            except Exception as e:                # noqa: BLE001
                rec.error = f"{type(e).__name__}: {e}"
                errors += 1
            self.records[key] = rec
        return {"harvested": harvested, "errors": errors,
                "skipped": skipped, "total": len(self.records)}

    def xla_bytes(self, key: Tuple) -> int:
        """Compiled bytes-accessed of one runner call (0 until the key
        is harvested) — the per-dispatch bytes total attribution splits."""
        rec = self.records.get(key)
        if rec is None or not rec.xla_bytes:
            return 0
        return int(rec.xla_bytes)

    # -- the drift report ----------------------------------------------

    def _flags(self, rec: CompiledCost) -> List[str]:
        import math
        flags: List[str] = []
        if rec.error is not None:
            return flags
        if rec.xla_flops is None:
            flags.append(FLAG_NO_XLA_FLOPS)
            return flags
        # a "block-sparse" layout whose compiled flop count lands at the
        # dense convention never skipped its cross-segment tiles
        backend = rec.key[7] if rec.key[0] == "packed" else None
        sparse_claimed = (backend in ("pallas", "auto")
                          and rec.analytic_body
                          < 0.97 * rec.analytic_dense_body)
        if sparse_claimed and rec.xla_flops >= 0.9 * rec.analytic_dense_body:
            flags.append(FLAG_COMPILED_DENSE)
        lo = min(rec.analytic_body_skip, rec.analytic_body)
        hi = max(rec.analytic_body, rec.analytic_dense_body)
        if rec.xla_flops > 0 and lo > 0:
            drift = max(math.log(rec.xla_flops / hi),
                        math.log(lo / rec.xla_flops), 0.0)
            if drift > DRIFT_LOG_RATIO:
                flags.append(FLAG_XLA_DRIFT)
        return flags

    def reconcile(self) -> Dict[str, Any]:
        """Per-step-family drift report: analytic vs XLA vs measured
        wall, plus summary ratios the profile bench gates."""
        rows: List[Dict[str, Any]] = []
        ratios: List[float] = []
        n_flagged = 0
        for key, rec in sorted(self.records.items(), key=lambda kv: repr(kv[0])):
            flags = self._flags(rec)
            n_flagged += bool(flags)
            row: Dict[str, Any] = {
                "label": rec.label, "family": rec.family,
                "analytic_body_gflops": rec.analytic_body / 1e9,
                "analytic_dispatch_gflops": rec.analytic_dispatch / 1e9,
                "flags": flags,
            }
            if rec.error is not None:
                row["error"] = rec.error
            if rec.xla_flops is not None:
                row["xla_gflops"] = rec.xla_flops / 1e9
                if rec.xla_over_analytic is not None:
                    row["xla_over_analytic"] = rec.xla_over_analytic
                    ratios.append(rec.xla_over_analytic)
            if rec.xla_bytes is not None:
                row["xla_mbytes"] = rec.xla_bytes / 1e6
            if rec.temp_bytes is not None:
                row["temp_mbytes"] = rec.temp_bytes / 1e6
            w = self.walls.get(key)
            if w is not None:
                row["wall_ms_ewma"] = w.ewma_s * 1e3
                row["wall_ms_min"] = w.min_s * 1e3
                row["dispatches"] = w.n
                if w.ewma_s > 0:
                    row["achieved_gflops_per_s"] = \
                        rec.analytic_dispatch / w.ewma_s / 1e9
                    row["wall_per_analytic_flop"] = \
                        w.ewma_s / max(rec.analytic_dispatch, 1.0)
            rows.append(row)
        out: Dict[str, Any] = {
            "rows": rows,
            "n_records": len(self.records),
            "n_errors": sum(1 for r in self.records.values()
                            if r.error is not None),
            "n_flagged": n_flagged,
        }
        if ratios:
            out["max_xla_over_analytic"] = max(ratios)
            out["min_xla_over_analytic"] = min(ratios)
        return out

    def report_lines(self) -> List[str]:
        """Human-readable drift report (the ``--profile`` serve print)."""
        rep = self.reconcile()
        lines = [f"[profile] {rep['n_records']} executables harvested, "
                 f"{rep['n_errors']} errors, {rep['n_flagged']} flagged"]
        for row in rep["rows"]:
            bits = [f"  {row['family']:>13} "
                    f"analytic={row['analytic_body_gflops']:.3f}G"]
            if "xla_gflops" in row:
                bits.append(f"xla={row['xla_gflops']:.3f}G "
                            f"(x{row.get('xla_over_analytic', 0.0):.2f})")
            if "wall_ms_ewma" in row:
                bits.append(f"wall={row['wall_ms_ewma']:.1f}ms "
                            f"({row.get('achieved_gflops_per_s', 0.0):.2f}"
                            f" GFLOP/s)")
            if row["flags"]:
                bits.append("FLAGS=" + ",".join(row["flags"]))
            if "error" in row:
                bits.append(f"ERROR={row['error']}")
            bits.append("| " + row["label"])
            lines.append(" ".join(bits))
        return lines
