"""Per-request served-cost attribution (DESIGN.md §profiling).

Splits each packed dispatch's measured cost — wall-clock, compiled
FLOPs, compiled bytes — across the requests in the pack by their
block-granular analytic ledger share (attention-skip- and
cache-refresh-aware weights computed by the engine), producing
per-request :class:`ServedCost` records with an **exact conservation
property**: for every dispatch, the attributed integer shares sum to
precisely the dispatch total. Dummy-slot padding and dispatch-wide
overhead (the deep-block branch a ``lax.cond`` runs for everyone when
anyone refreshes) smear proportionally over the real requests — that
*is* the attribution: a request is charged for the hardware cost its
presence in the pack implied, not only its private arithmetic.

Exactness is engineered, not hoped for: totals are attributed as
integers (wall in nanoseconds, FLOPs and bytes as integer counts) via
largest-remainder apportionment (:func:`exact_shares`), so conservation
is integer equality — no float non-associativity, no epsilon.

This module is deliberately **host-pure**: no jax, no numpy, no device
values. It runs on the serving hot path after each dispatch, and the
``telemetry-attribution-device`` lint rule
(``analysis/rules_telemetry.py``) statically rejects any edit that
would let it force a device sync.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple


def exact_shares(total: int, weights: Sequence[float]) -> List[int]:
    """Apportion integer ``total`` across ``weights`` by the
    largest-remainder method. The returned shares are non-negative ints
    summing EXACTLY to ``total``; zero/degenerate weights fall back to
    an equal split. Ties in fractional remainder break toward earlier
    indices (deterministic)."""
    n = len(weights)
    if n == 0:
        return []
    wsum = float(sum(w for w in weights if w > 0))
    if wsum <= 0:
        weights = [1.0] * n
        wsum = float(n)
    quotas = [total * max(float(w), 0.0) / wsum for w in weights]
    shares = [int(q) for q in quotas]
    leftover = total - sum(shares)
    # leftover in [0, n): hand one unit each to the largest remainders
    order = sorted(range(n), key=lambda i: (shares[i] - quotas[i], i))
    for i in range(leftover):
        shares[order[i]] += 1
    return shares


@dataclasses.dataclass
class ServedCost:
    """What serving one request actually cost, measured."""
    request_id: int
    flops: int = 0                  # attributed compiled FLOPs
    bytes: int = 0                  # attributed compiled bytes accessed
    wall_ns: int = 0                # attributed dispatch wall-clock
    dispatches: int = 0             # packed dispatches this request rode
    queue_wait_s: float = 0.0       # arrival -> admission
    budget: Optional[str] = None

    @property
    def wall_ms(self) -> float:
        return self.wall_ns / 1e6


@dataclasses.dataclass
class DispatchRecord:
    """One dispatch's attribution, kept (bounded) for the post-mortem
    bundle and the bench conservation check."""
    time: float
    label: str
    wall_ns: int
    flops: int
    bytes: int
    request_ids: Tuple[int, ...]
    shares_wall_ns: Tuple[int, ...]
    shares_flops: Tuple[int, ...]
    shares_bytes: Tuple[int, ...]

    @property
    def conserved(self) -> bool:
        return (sum(self.shares_wall_ns) == self.wall_ns
                and sum(self.shares_flops) == self.flops
                and sum(self.shares_bytes) == self.bytes)


class AttributionLedger:
    """Accumulates per-request attributed cost across dispatches and
    finalizes a :class:`ServedCost` when the request retires."""

    def __init__(self, max_dispatch_records: int = 1024):
        self._open: Dict[int, ServedCost] = {}
        self.finalized: Dict[int, ServedCost] = {}
        self.dispatches: Deque[DispatchRecord] = deque(
            maxlen=max_dispatch_records)
        self.total_wall_ns = 0
        self.total_flops = 0
        self.total_bytes = 0

    def attribute_dispatch(self, *, time: float, label: str,
                           request_ids: Sequence[int],
                           weights: Sequence[float], wall_ns: int,
                           flops: int,
                           bytes_: int = 0) -> DispatchRecord:
        """Split one dispatch's totals over ``request_ids`` by
        ``weights`` (each request's refresh-aware analytic cost share).
        Conservation per component is exact by construction."""
        sw = exact_shares(int(wall_ns), weights)
        sf = exact_shares(int(flops), weights)
        sb = exact_shares(int(bytes_), weights)
        for rid, w_ns, fl, by in zip(request_ids, sw, sf, sb):
            cost = self._open.get(rid)
            if cost is None:
                cost = self._open[rid] = ServedCost(request_id=rid)
            cost.wall_ns += w_ns
            cost.flops += fl
            cost.bytes += by
            cost.dispatches += 1
        self.total_wall_ns += int(wall_ns)
        self.total_flops += int(flops)
        self.total_bytes += int(bytes_)
        rec = DispatchRecord(
            time=time, label=label, wall_ns=int(wall_ns),
            flops=int(flops), bytes=int(bytes_),
            request_ids=tuple(request_ids),
            shares_wall_ns=tuple(sw), shares_flops=tuple(sf),
            shares_bytes=tuple(sb))
        self.dispatches.append(rec)
        return rec

    def finalize(self, request_id: int, *, queue_wait_s: float = 0.0,
                 budget: Optional[str] = None) -> ServedCost:
        """Close out a retiring request's record (idempotent — a request
        that never rode a dispatch finalizes to zeros)."""
        cost = self._open.pop(request_id, None)
        if cost is None:
            cost = self.finalized.get(request_id,
                                      ServedCost(request_id=request_id))
        cost.queue_wait_s = queue_wait_s
        cost.budget = budget
        self.finalized[request_id] = cost
        return cost

    # -- conservation & reporting --------------------------------------

    def conservation(self) -> Dict[str, int]:
        """Ledger-wide conservation check: attributed totals (open +
        finalized) vs dispatch totals. All deltas are exactly 0 by
        construction; the tier-1 tests and the profile bench assert it."""
        att_wall = att_flops = att_bytes = 0
        for cost in list(self._open.values()) + list(
                self.finalized.values()):
            att_wall += cost.wall_ns
            att_flops += cost.flops
            att_bytes += cost.bytes
        return {
            "wall_ns_delta": att_wall - self.total_wall_ns,
            "flops_delta": att_flops - self.total_flops,
            "bytes_delta": att_bytes - self.total_bytes,
        }

    def snapshot(self) -> Dict[str, object]:
        """Flight-recorder view: totals, open requests, recent
        dispatch records."""
        return {
            "totals": {"wall_ns": self.total_wall_ns,
                       "flops": self.total_flops,
                       "bytes": self.total_bytes},
            "conservation": self.conservation(),
            "open": {rid: dataclasses.asdict(c)
                     for rid, c in self._open.items()},
            "n_finalized": len(self.finalized),
            "recent_dispatches": [
                {"time": d.time, "label": d.label, "wall_ns": d.wall_ns,
                 "flops": d.flops, "bytes": d.bytes,
                 "request_ids": list(d.request_ids)}
                for d in list(self.dispatches)[-32:]],
        }
