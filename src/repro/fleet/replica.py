"""One fleet replica: a serving engine + its own clock + its price tag.

Two engine kinds sit behind the same pump/submit surface:

* ``packed`` — the continuous-batching :class:`ServingEngine` (the
  normal case; single-replica-equivalent bit-identical sampling);
* ``fixed`` — :class:`FixedSlotEngine`, a per-level fixed-slot batcher
  driving ``FlexiPipeline.sample`` directly. It exists because packed
  engines reject sequence-parallel plans (``plan.parallel`` needs a
  shard_map over the replica's device slice), so a ``--mesh DATAxSEQ
  --replicas N`` fleet runs one fixed-slot engine per seq-wide replica
  mesh.

**Virtual time.** A single-process fleet shares one accelerator, so
replica compute serializes and wall-clock can never show N-replica
throughput. Each replica therefore owns a :class:`ReplicaClock` that
the pump advances by the *modeled* dispatch cost — packed tokens x
calibrated seconds-per-token (x the replica's ``speed_factor``, the
straggler dial). Fleet makespan is the max replica clock; on a real
multi-host deployment every replica has its own chips, the virtual
clock is replaced by ``time.monotonic``, and the same arithmetic holds
with dt measured instead of modeled (``virtual=False``).

**Pricing.** Every replica carries its own
:class:`~repro.serving.controller.BudgetController` and feeds it
wall-per-analytic-FLOP calibration (PR 8's seconds-space pricing) from
its own observed/modeled seconds-per-token, so
``controller.cost_seconds(level)`` is the per-replica price the router
scores placements with — a slow replica literally costs more seconds.
"""
from __future__ import annotations

import math
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import dit_nfe_flops
from repro.diffusion import schedule as sch
from repro.models import dit as dit_mod
from repro.pipeline.pipeline import FlexiPipeline
from repro.pipeline.plan import SamplingPlan
from repro.serving.controller import BudgetController
from repro.serving.metrics import RequestRecord, ServingMetrics
from repro.serving.queue import Request, RequestQueue
from repro.serving.scheduler import LevelPlan, ServedResult, ServingEngine

ENGINE_KINDS = ("packed", "fixed")

#: pre-measurement seconds-per-token guess (only prices the very first
#: placements in wall mode; the EWMA takes over after one dispatch)
DEFAULT_SECONDS_PER_TOKEN = 1e-4


class ReplicaClock:
    """Per-replica monotonic virtual clock (callable like
    ``time.monotonic``); the pump advances it by modeled dispatch cost."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t

    def catch_up(self, t: float) -> None:
        """A replica can't run work that hasn't arrived yet: placement
        at fleet time ``t`` pulls an idle replica's clock forward."""
        if t > self.t:
            self.t = float(t)


def _level_plans(cfg, sched, plans: Dict[float, SamplingPlan]
                 ) -> Dict[float, LevelPlan]:
    """Resolved per-level step ladders (the packed engine builds these
    itself; the fixed-slot engine and the replica price model need the
    same view)."""
    out: Dict[float, LevelPlan] = {}
    for b in sorted(plans):
        plan = plans[b]
        fs = plan.resolve_schedule(cfg)
        ts = sch.respaced_timesteps(sched.num_steps, plan.T)
        step_modes = np.concatenate(
            [np.full(n, m, np.int64) for m, n in fs.phases if n])
        run_len = np.ones(len(step_modes), np.int64)
        for i in range(len(step_modes) - 2, -1, -1):
            if step_modes[i] == step_modes[i + 1]:
                run_len[i] = run_len[i + 1] + 1
        out[b] = LevelPlan(level=b, plan=plan, ts=ts,
                           t_prev=np.concatenate([ts[1:], [-1]]),
                           modes=step_modes, run_len=run_len,
                           flops=plan.flops(cfg))
    return out


class FixedSlotEngine:
    """Legacy fixed-slot batcher with the packed engine's fleet surface
    (submit/step/extract_queued/stop_admissions/metrics).

    Each step serves one same-level batch of up to ``batch_size``
    requests through ``pipe.sample``. With the ``ddim`` solver the batch
    stacks each request's OWN prior draw (``x_T`` rows from the request
    key), so results match a standalone single-request ``sample`` —
    re-admission after a kill reproduces the reference. (``ddpm``
    ancestral noise is batch-keyed by ``sample``; per-request ddpm
    determinism under rebatching is what the packed engine is for.)
    """

    def __init__(self, pipe: FlexiPipeline,
                 plans: Dict[float, SamplingPlan], *,
                 batch_size: int = 4,
                 clock: Optional[Callable[[], float]] = None,
                 base_key: Optional[jax.Array] = None):
        self.pipe = pipe
        self.cfg = pipe.cfg
        self.clock = clock or time.monotonic
        self.batch_size = int(batch_size)
        ref = next(iter(plans.values()))
        self.guided = ref.guidance_active
        self.levels = _level_plans(self.cfg, pipe.sched, plans)
        self.metrics = ServingMetrics()
        self._queue = RequestQueue()
        self._admitting = True
        self._next_id = 0
        self._base_key = (base_key if base_key is not None
                          else jax.random.PRNGKey(0x5e41))

    # -- request lifecycle (packed-engine surface) ---------------------

    def quantize(self, budget: float) -> float:
        for b in sorted(self.levels):
            if b >= budget - 1e-9:
                return b
        return max(self.levels)

    def submit(self, cond: int, budget: float,
               deadline: float = math.inf,
               key: Optional[jax.Array] = None) -> int:
        rid = self._next_id
        self._next_id += 1
        if key is None:
            key = jax.random.fold_in(self._base_key, rid)
        req = Request(id=rid, cond=int(cond), budget=float(budget),
                      deadline=deadline, key=key)
        self._queue.submit(req, self.clock())
        return rid

    def stop_admissions(self) -> None:
        self._admitting = False

    def resume_admissions(self) -> None:
        self._admitting = True

    def extract_queued(self) -> List[Request]:
        out = sorted(self._queue._pending, key=lambda r: r._seq)
        self._queue._pending.clear()
        return out

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def n_inflight(self) -> int:
        return 0                      # a fixed-slot step runs to finish

    @property
    def idle(self) -> bool:
        return not self._queue

    def cache_stats(self) -> Dict[str, int]:
        return self.pipe.cache_stats()

    # -- the iteration -------------------------------------------------

    def step(self) -> List[ServedResult]:
        """Serve one fixed-slot batch: the level with the oldest pending
        request, filled to ``batch_size`` in arrival order."""
        now = self.clock()
        if not self._queue:
            return []
        pending = sorted(self._queue._pending, key=lambda r: r._seq)
        level = self.quantize(pending[0].budget)
        batch = [r for r in pending
                 if self.quantize(r.budget) == level][:self.batch_size]
        for r in batch:
            self._queue._pending.remove(r)
        lp = self.levels[level]
        n = len(batch)
        cond = jnp.asarray([r.cond for r in batch])
        if lp.plan.solver == "ddim":
            x_T = jnp.concatenate([
                jax.random.normal(r.key, (1,) + self.cfg.dit.latent_shape)
                for r in batch])
        else:
            x_T = None
        res = self.pipe.sample(lp.plan, n, batch[0].key, cond=cond,
                               x_T=x_T)
        jax.block_until_ready(res.x0)
        finish = self.clock()
        mult = 2 if self.guided else 1
        tokens_each = int(mult * sum(
            dit_mod.tokens_for_mode(self.cfg, int(m)) for m in lp.modes))
        self.metrics.record_step(finish, tokens_each * n, tokens_each * n,
                                 n)
        out: List[ServedResult] = []
        for i, r in enumerate(batch):
            rec = RequestRecord(
                id=r.id, arrival=r.arrival, admit=now, finish=finish,
                deadline=r.deadline, budget_requested=r.budget,
                budget_served=level, tokens=tokens_each, flops=lp.flops)
            self.metrics.record_request(rec)
            out.append(ServedResult(request=r, x0=res.x0[i],
                                    budget_served=level, record=rec))
        return out

    def run(self, max_steps: int = 100_000) -> List[ServedResult]:
        out: List[ServedResult] = []
        steps = 0
        while self._queue and steps < max_steps:
            out.extend(self.step())
            steps += 1
        return out


class Replica:
    """Engine + clock + price model, pumped by the fleet driver."""

    def __init__(self, rid: int, pipe: FlexiPipeline,
                 plans: Dict[float, SamplingPlan], *,
                 engine_kind: str = "packed",
                 virtual: bool = True,
                 seconds_per_token: float = DEFAULT_SECONDS_PER_TOKEN,
                 speed_factor: float = 1.0,
                 clock: Optional[Callable[[], float]] = None,
                 controller: Optional[BudgetController] = None,
                 base_key: Optional[jax.Array] = None,
                 batch_size: int = 4,
                 faults: Optional[Any] = None,
                 engine_kwargs: Optional[Dict[str, Any]] = None):
        if engine_kind not in ENGINE_KINDS:
            raise ValueError(f"unknown engine kind {engine_kind!r}; "
                             f"known: {ENGINE_KINDS}")
        self.rid = rid
        self.virtual = virtual
        self.speed_factor = float(speed_factor)
        # per-replica fault facade (resilience/faults.ReplicaFaults);
        # None on every production path — the seams below are no-ops then
        self.faults = faults
        kw = dict(engine_kwargs or {})
        cache = kw.get("cache")
        if virtual:
            t0 = clock() if clock is not None else 0.0
            self.rclock: Callable[[], float] = ReplicaClock(t0)
        else:
            self.rclock = clock or time.monotonic
        self.controller = controller if controller is not None else \
            BudgetController(
                pipe.cfg, plans, cache=cache,
                num_train_steps=pipe.sched.num_steps,
                attn_backend=next(iter(plans.values())).attn_backend)
        if engine_kind == "packed":
            self.engine: Any = ServingEngine(
                pipe, plans, clock=self.rclock,
                controller=self.controller, base_key=base_key, **kw)
            self._levels = self.engine.levels
            guided = self.engine.guided
        else:
            self.engine = FixedSlotEngine(pipe, plans,
                                          batch_size=batch_size,
                                          clock=self.rclock,
                                          base_key=base_key)
            self._levels = self.engine.levels
            guided = self.engine.guided
        cfg = pipe.cfg
        mult = 2 if guided else 1
        self._level_tokens = {
            b: int(mult * sum(dit_mod.tokens_for_mode(cfg, int(m))
                              for m in lp.modes))
            for b, lp in self._levels.items()}
        # wall-per-FLOP feeds: per patch mode, FLOPs carried by one of
        # its (guidance-multiplied) segment tokens — the bridge from the
        # seconds-per-token cost model into the controller's
        # seconds-space pricing
        backend = next(iter(plans.values())).attn_backend
        modes = sorted({int(m) for lp in self._levels.values()
                        for m in lp.modes})
        self._flops_per_token = {
            m: dit_nfe_flops(cfg, m, attn_backend=backend)
            / dit_mod.tokens_for_mode(cfg, m) for m in modes}
        self._spt = float(seconds_per_token)
        self._measured = virtual     # virtual spt is authoritative now
        if virtual:
            self._calibrate()

    # ------------------------------------------------------------------
    # Pricing

    def _calibrate(self) -> None:
        spt = self._spt * (self.speed_factor if self.virtual else 1.0)
        for m, fpt in self._flops_per_token.items():
            self.controller.observe_calibration(m, fpt, spt)

    @property
    def seconds_per_token(self) -> float:
        return self._spt * (self.speed_factor if self.virtual else 1.0)

    def price_seconds(self, level: float) -> float:
        """Calibrated seconds one request at ``level`` costs here."""
        c = self.controller.cost_seconds(level)
        if c is not None:
            return float(c)
        return self._level_tokens[level] * self.seconds_per_token

    def prices(self) -> Dict[float, float]:
        return {b: self.price_seconds(b) for b in self._levels}

    def backlog_seconds(self) -> float:
        """Priced not-yet-done work: queued requests at full price,
        in-flight ones at their remaining-step fraction."""
        total = 0.0
        for r in self.engine._queue._pending:
            total += self.price_seconds(self.engine.quantize(r.budget))
        for f in getattr(self.engine, "_inflight", ()):
            frac = 1.0 - f.step / max(len(f.lp.ts), 1)
            total += self.price_seconds(f.lp.level) * frac
        return total

    # ------------------------------------------------------------------
    # Fleet surface

    def submit(self, cond: int, budget: float, deadline: float,
               key: jax.Array) -> int:
        return self.engine.submit(cond, budget, deadline=deadline, key=key)

    @property
    def has_work(self) -> bool:
        return not self.engine.idle

    def pump(self, now: float) -> Tuple[List[ServedResult], float]:
        """One engine iteration at fleet time ``now``; returns the
        finished results and the dispatch's (modeled or measured)
        seconds. The replica clock never runs behind fleet time."""
        if self.virtual:
            self.rclock.catch_up(now)
        t0 = self.rclock()
        n0 = self.engine.metrics.total_steps
        results = self.engine.step()
        dt = 0.0
        if self.engine.metrics.total_steps > n0:
            srec = self.engine.metrics.steps[-1]
            if self.virtual:
                dt = (srec.packed_tokens * self._spt * self.speed_factor)
                # fault seam: a scripted slowdown window stretches the
                # modeled dispatch cost (the straggler detector and the
                # router's backlog pricing both see it)
                if self.faults is not None:
                    dt *= self.faults.slowdown_factor(t0)
                self.rclock.advance(dt)
            else:
                dt = self.rclock() - t0
                if srec.packed_tokens > 0 and dt > 0:
                    m = dt / srec.packed_tokens
                    self._spt = (m if not self._measured
                                 else 0.7 * self._spt + 0.3 * m)
                    self._measured = True
                    self._calibrate()
        return results, dt

    def estimated_finish(self, engine_id: int, now: float
                         ) -> Optional[float]:
        """Predicted completion time of an in-flight/queued request on
        this replica: remaining tokens x seconds-per-token, behind the
        current backlog. None when unknown here."""
        eng = self.engine
        spt = self.seconds_per_token
        for f in getattr(eng, "_inflight", ()):
            if f.req.id == engine_id:
                mult = 2 if eng.guided else 1
                rem = mult * sum(
                    dit_mod.tokens_for_mode(eng.cfg, int(m))
                    for m in f.lp.modes[f.step:])
                return max(now, self.rclock()) + rem * spt
        for r in eng._queue._pending:
            if r.id == engine_id:
                level = eng.quantize(r.budget)
                return (max(now, self.rclock()) + self.backlog_seconds()
                        + self._level_tokens[level] * spt)
        return None

    def compile_stats(self) -> Dict[str, int]:
        return self.engine.cache_stats()
