"""Background warm-set compilation (DESIGN.md §fleet, ROADMAP thread).

``precapture_warm_set`` walks the small-cohort bucket ladder — every
fine layout a mid-trace join might need — but doing it synchronously
holds the replica's startup for the whole ladder. The
:class:`BackgroundCompiler` moves that walk off the startup path: a
daemon thread per replica takes the engine's
:meth:`~repro.serving.scheduler.ServingEngine.warm_set_ladder` work
list and captures one rung at a time (``_dummy_dispatch(record=False)``
— no spans: the thread must not interleave writes into the serving
thread's recorder ring) while the replica already serves.

Safety: the only shared mutable state is ``FlexiPipeline``'s runner
cache, whose miss/insert path is serialized by the pipeline's cache
lock — if the serving thread needs a rung first, it compiles it, the
warm thread sees it warm and skips it, and the compile counters stay
exact. Once :meth:`wait` returns, :meth:`assert_warm` proves the ladder
is fully captured, and the zero-recompile invariant holds for every
subsequent small-cohort dispatch (asserted in tests/test_fleet.py and
the fleet bench).
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence


class BackgroundCompiler:
    """Walks one engine's cold warm-set ladder on a daemon thread.

    >>> warm = BackgroundCompiler(engine).start()
    >>> ... serve traffic ...
    >>> warm.wait(); warm.assert_warm()
    """

    def __init__(self, engine, *, max_per_mode: int = 2,
                 k_depths: Optional[Sequence[int]] = None,
                 name: Optional[str] = None):
        self.engine = engine
        self.max_per_mode = max_per_mode
        self.k_depths = list(k_depths) if k_depths is not None else None
        self.captured = 0            # rungs this thread compiled itself
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._run, daemon=True,
            name=name or "fleet-warm")

    def start(self) -> "BackgroundCompiler":
        self._thread.start()
        return self

    def _run(self) -> None:
        try:
            for layout, k in self.engine.warm_set_ladder(
                    self.max_per_mode, self.k_depths):
                if self._stop.is_set():
                    return
                if self.engine._is_warm(layout, k):
                    continue          # serving thread captured it first
                self.engine._dummy_dispatch(layout, k, record=False)
                self.captured += 1
        except BaseException as e:    # surfaced on wait(), never lost
            self._err = e

    def stop(self) -> None:
        """Ask the walk to end after the current rung (drain/shutdown)."""
        self._stop.set()

    @property
    def done(self) -> bool:
        return not self._thread.is_alive()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Join the thread; re-raises anything it hit. Returns False on
        timeout (thread still walking)."""
        self._thread.join(timeout)
        if self._thread.is_alive():
            return False
        if self._err is not None:
            raise self._err
        return True

    def assert_warm(self) -> int:
        """Every ladder rung must now be warm: any residual cold rung
        would turn into a compile stall (a recompile by the serving
        thread's counters) mid-traffic. Returns the rung count proven
        warm."""
        residual = self.engine.warm_set_ladder(self.max_per_mode,
                                               self.k_depths)
        if residual:
            raise AssertionError(
                f"warm-set ladder not fully captured: "
                f"{len(residual)} cold rung(s), first "
                f"{residual[0][0].groups} k={residual[0][1]}")
        n = 0
        for layout in self.engine.menu.layouts:
            if all(c <= self.max_per_mode for _m, c in layout.groups):
                n += 1
        return n
