"""Fleet membership: drain/join/death state machine — HOST-PURE.

Wires two seed runtime modules into serving:

* :class:`repro.runtime.fault_tolerance.HeartbeatMonitor` is the
  liveness source of truth. In-process replicas "heartbeat" every time
  the fleet driver pumps them; a replica that stops being pumped (hung,
  killed) misses beats and :meth:`FleetMembership.check` declares it
  dead after ``timeout_s`` on the injected clock. The monitor's
  incarnation counter survives a comeback, so stale completions from a
  previous incarnation are droppable.
* :func:`repro.runtime.elastic.plan_mesh_shape` plans the device
  partition: ``(data, seq) = plan_mesh_shape(n_devices, seq_parallel)``
  caps how many sequence-parallel replicas the device pool sustains;
  each replica owns a contiguous ``seq``-wide device slice. On replica
  loss the surviving partition is replanned the same way, which is
  exactly what transfers to a real cluster.

Replica lifecycle::

    active --start_drain--> draining --finish_drain--> drained
    active/draining --(missed beats | mark_dead)--> dead --rejoin--> active

A *draining* replica stops taking placements but keeps finishing its
in-flight cohort; a *dead* one is gone now — its accepted-but-unfinished
requests are the router's to re-admit (see fleet.fleet).

The module is host-pure (``fleet-host-pure`` lint): it reasons about
integer device *ids*, never device objects. :func:`init_process_group`
is the ``jax.distributed``-shaped seam — in-process fleets get a
simulated group; a real multi-host launcher passes
``jax.distributed.initialize`` (same keyword surface) and runs one
process per replica.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.runtime.elastic import plan_mesh_shape
from repro.runtime.fault_tolerance import HeartbeatMonitor, WorkerState

REPLICA_STATES = ("active", "draining", "drained", "dead")


@dataclasses.dataclass(frozen=True)
class ProcessGroup:
    """What ``jax.distributed.initialize`` would have established."""
    coordinator_address: str
    num_processes: int
    process_id: int
    simulated: bool


def init_process_group(coordinator_address: str = "local://fleet",
                       num_processes: int = 1, process_id: int = 0,
                       initialize_fn: Optional[Callable] = None
                       ) -> ProcessGroup:
    """The multi-host init seam. In-process fleets (this repo's runnable
    configuration) pass no ``initialize_fn`` and get a simulated group.
    A real launcher passes ``jax.distributed.initialize`` here — the
    keyword surface matches — and each process then builds ONE replica
    over its local devices instead of N over subsets."""
    if initialize_fn is not None:
        initialize_fn(coordinator_address=coordinator_address,
                      num_processes=num_processes, process_id=process_id)
        return ProcessGroup(coordinator_address, num_processes,
                            process_id, simulated=False)
    return ProcessGroup(coordinator_address, num_processes, process_id,
                        simulated=True)


def partition_devices(device_ids: Sequence[int], n_replicas: int,
                      seq_parallel: int = 1
                      ) -> List[Tuple[int, ...]]:
    """Contiguous ``seq_parallel``-wide device slices, one per replica,
    feasibility-checked through :func:`plan_mesh_shape` (the same
    planner elastic restore uses, so a post-loss replan agrees with
    training-side rescale)."""
    data, seq = plan_mesh_shape(len(device_ids), seq_parallel)
    if seq != seq_parallel:
        raise ValueError(
            f"seq_parallel={seq_parallel} does not divide "
            f"{len(device_ids)} devices (plan_mesh_shape says "
            f"{(data, seq)})")
    if n_replicas > data:
        raise ValueError(f"{n_replicas} replicas x {seq_parallel} devices "
                         f"need {n_replicas * seq_parallel}, have "
                         f"{len(device_ids)}")
    ids = list(device_ids)
    return [tuple(ids[i * seq:(i + 1) * seq]) for i in range(n_replicas)]


@dataclasses.dataclass
class ReplicaInfo:
    rid: int
    device_ids: Tuple[int, ...]
    state: str = "active"


class FleetMembership:
    """Replica states + heartbeat liveness over an injectable clock."""

    def __init__(self, n_replicas: int, device_ids: Sequence[int], *,
                 seq_parallel: int = 1, timeout_s: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.seq_parallel = seq_parallel
        parts = partition_devices(device_ids, n_replicas, seq_parallel)
        self.replicas: Dict[int, ReplicaInfo] = {
            i: ReplicaInfo(i, parts[i]) for i in range(n_replicas)}
        self.monitor = HeartbeatMonitor(n_replicas, timeout_s, clock)

    # ------------------------------------------------------------------
    # Liveness

    def beat(self, rid: int, at: Optional[float] = None) -> None:
        """Deliver a heartbeat; ``at`` is the sender's send-time for
        delayed/out-of-order delivery (the monitor max-guards the stamp,
        so duplicates and stale beats are harmless)."""
        if self.replicas[rid].state in ("active", "draining"):
            self.monitor.heartbeat(rid, at=at)

    def check(self) -> List[int]:
        """Newly dead replica ids (missed-heartbeat path); marks them."""
        dead = [r for r in self.monitor.check()
                if self.replicas[r].state not in ("dead", "drained")]
        for r in dead:
            self.replicas[r].state = "dead"
        return dead

    def mark_dead(self, rid: int) -> None:
        """Explicit kill (the crash was observed, not inferred)."""
        self.replicas[rid].state = "dead"
        self.monitor.workers[rid].alive = False

    def incarnation(self, rid: int) -> int:
        return self.monitor.workers[rid].incarnation

    # ------------------------------------------------------------------
    # Drain / join

    def start_drain(self, rid: int) -> None:
        info = self.replicas[rid]
        if info.state != "active":
            raise RuntimeError(f"replica {rid} is {info.state}; only an "
                               f"active replica can start draining")
        info.state = "draining"

    def finish_drain(self, rid: int) -> None:
        info = self.replicas[rid]
        if info.state != "draining":
            raise RuntimeError(f"replica {rid} is {info.state}, not "
                               f"draining")
        info.state = "drained"

    def rejoin(self, rid: int) -> int:
        """Bring a dead/drained replica back (same device slice); the
        monitor bumps its incarnation so pre-death attribution can't be
        confused with the new life. Returns the new incarnation."""
        info = self.replicas[rid]
        # heartbeat() on a dead worker revives it and bumps incarnation —
        # exactly the comeback semantics we want; on a drained one it
        # just refreshes the stamp
        self.monitor.heartbeat(rid)
        info.state = "active"
        return self.monitor.workers[rid].incarnation

    def join(self, device_ids: Sequence[int]) -> int:
        """Admit a brand-new replica over ``device_ids``; returns its
        id. The monitor grows — fresh incarnation 0."""
        _data, seq = plan_mesh_shape(len(device_ids), self.seq_parallel)
        if seq != self.seq_parallel:
            raise ValueError(
                f"seq_parallel={self.seq_parallel} does not divide the "
                f"joining replica's {len(device_ids)} devices "
                f"(plan_mesh_shape says {(_data, seq)})")
        rid = max(self.replicas) + 1 if self.replicas else 0
        self.replicas[rid] = ReplicaInfo(rid, tuple(device_ids))
        self.monitor.workers[rid] = WorkerState(rid, self.clock())
        return rid

    # ------------------------------------------------------------------

    def state(self, rid: int) -> str:
        return self.replicas[rid].state

    def admitting(self, rid: int) -> bool:
        """Can the router place new work here?"""
        return (self.replicas[rid].state == "active"
                and self.monitor.workers[rid].alive)

    def pumpable(self, rid: int) -> bool:
        """Should the driver keep stepping this replica's engine?"""
        return self.replicas[rid].state in ("active", "draining")

    @property
    def alive_count(self) -> int:
        return sum(1 for i in self.replicas
                   if self.replicas[i].state in ("active", "draining")
                   and self.monitor.workers[i].alive)

    def summary(self) -> Dict[str, object]:
        return {
            "replicas": {str(i): {"state": info.state,
                                  "devices": list(info.device_ids),
                                  "incarnation": self.incarnation(i)}
                         for i, info in sorted(self.replicas.items())},
            "alive": self.alive_count,
        }
