"""Fleet request router (DESIGN.md §fleet) — HOST-PURE.

One front door, N replica engines. The router owns the fleet-level
request ledger and the placement decision; the per-replica admission
queues live inside the engines themselves (a placement is an
``engine.submit`` by the fleet driver). Everything here is plain host
bookkeeping: PRNG keys pass through as opaque objects, timestamps come
from the caller's clock, and the module must survive the
``fleet-host-pure`` lint (no jax, no numpy, no device syncs) — routing
runs once per scheduling round on the serving hot path.

Placement scoring (policy ``cheapest``)::

    score(replica) = (backlog_seconds + price_seconds[level]) * weight

``backlog_seconds`` is the replica's priced queue+in-flight work and
``price_seconds`` the per-level cost, both in the replica
``BudgetController``'s calibrated seconds (measured wall-per-FLOP, PR 8);
``weight >= 1`` is the straggler down-weight from ``fleet.health``. The
``affinity`` policy additionally pins a request to its *home* replica —
the replica that first dispatched it, where its ``CacheStore`` slots
live — and shards fresh requests by class label so repeat conditions
land together; ``rr`` is round-robin over admitting replicas.

Cache affinity is measured per request-dispatch: every dispatch runs on
the replica owning the request's cache slots *except* the first dispatch
after a placement that abandoned established state (a dead replica's
re-admission, which forces a refresh). So
``hit_rate = 1 - state_readmits / total_request_dispatches``; handing
back a still-queued request (drain) moves no state and costs nothing.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

ROUTER_POLICIES = ("cheapest", "affinity", "rr")


@dataclasses.dataclass
class FleetRequest:
    """Fleet-level lifecycle record; ``key`` is the request's PRNG key
    (opaque here) — it rides through every re-admission, so a restarted
    request reproduces the uninterrupted trajectory bit-for-bit."""
    rid: int
    cond: int
    budget: float
    deadline: float
    key: Any
    arrival: float
    state: str = "pending"        # pending | placed | done | expired
    owner: int = -1               # replica currently responsible
    engine_id: int = -1           # request id inside the owner's engine
    home: int = -1                # affinity home (first placement)
    dispatched: bool = False      # has device/cache state on the owner
    placements: int = 0
    handbacks: int = 0            # drain handbacks (stateless)
    readmits: int = 0             # death re-admissions (state lost)
    hedged: bool = False
    hedge_owner: int = -1
    hedge_engine_id: int = -1
    served_by: int = -1
    done_at: float = math.nan
    # quarantine escalation (DESIGN.md §resilience): a non-finite latent
    # re-admits the request at the most powerful level; ``not_before``
    # is its deadline-aware backoff gate for the next placement round
    retries: int = 0
    escalated: bool = False
    not_before: float = 0.0


@dataclasses.dataclass
class ReplicaView:
    """One replica's routing snapshot for a placement round. Mutable on
    purpose: the router charges each placement's price onto the view's
    backlog so a burst placed in one round spreads instead of piling
    onto whoever was cheapest at the round's start."""
    rid: int
    admitting: bool
    backlog_seconds: float
    prices: Dict[float, float]    # menu level -> calibrated seconds
    weight: float = 1.0           # straggler down-weight (>= 1 is slow)

    def score(self, level: float) -> float:
        return (self.backlog_seconds
                + self.prices.get(level, 0.0)) * self.weight


class Router:
    """Placement policy + fleet request ledger."""

    def __init__(self, policy: str = "cheapest"):
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; known: "
                             f"{ROUTER_POLICIES}")
        self.policy = policy
        self.requests: Dict[int, FleetRequest] = {}
        self._pending: List[int] = []
        self._next_id = 0
        self._rr = 0
        # affinity / churn counters (see module docstring for hit rate)
        self.placements = 0
        self.affine_placements = 0
        self.state_readmits = 0
        self.handbacks = 0
        self.hedges = 0
        self.hedge_wins = 0
        # resilience counters
        self.escalations = 0
        self.escalation_overflows = 0
        self.expirations = 0

    # ------------------------------------------------------------------
    # Ledger

    def register(self, cond: int, budget: float, deadline: float,
                 key: Any, now: float) -> FleetRequest:
        req = FleetRequest(rid=self._next_id, cond=int(cond),
                           budget=float(budget), deadline=deadline,
                           key=key, arrival=now)
        self._next_id += 1
        self.requests[req.rid] = req
        self._pending.append(req.rid)
        return req

    def pending(self, now: Optional[float] = None) -> List[FleetRequest]:
        """Routable pending requests; with ``now`` given, requests still
        inside their escalation backoff window are held back."""
        reqs = [self.requests[r] for r in self._pending]
        if now is None:
            return reqs
        return [r for r in reqs if r.not_before <= now]

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def unfinished(self) -> List[FleetRequest]:
        return [r for r in self.requests.values()
                if r.state not in ("done", "expired")]

    # ------------------------------------------------------------------
    # Placement

    def _choose(self, req: FleetRequest, views: List[ReplicaView],
                level: float) -> ReplicaView:
        live = sorted((v for v in views if v.admitting),
                      key=lambda v: v.rid)
        if not live:
            raise RuntimeError("no admitting replica to place on")
        if self.policy == "rr":
            v = live[self._rr % len(live)]
            self._rr += 1
            return v
        cheapest = min(live, key=lambda v: (v.score(level), v.rid))
        if self.policy == "affinity":
            by_rid = {v.rid: v for v in live}
            if req.home in by_rid:
                return by_rid[req.home]      # sticky: slots live there
            # fresh request: shard by class label so repeat conditions
            # share a replica (warm executables, dense cohorts) — unless
            # that shard is badly behind the cheapest choice
            shard = live[req.cond % len(live)]
            if shard.score(level) <= 2.0 * cheapest.score(level) + 1e-12:
                return shard
        return cheapest

    def place(self, req: FleetRequest, views: List[ReplicaView],
              level: float) -> int:
        """Place one pending request; returns the chosen replica id and
        charges its price onto that replica's view backlog."""
        if req.state != "pending":
            raise RuntimeError(f"request {req.rid} is {req.state}, "
                               f"not pending")
        v = self._choose(req, views, level)
        self.placements += 1
        if req.home < 0:
            req.home = v.rid
            self.affine_placements += 1
        elif v.rid == req.home:
            self.affine_placements += 1
        else:
            # moving an established request: only costs cache state if it
            # ever dispatched (slots allocated) on the old home
            if req.dispatched:
                self.state_readmits += 1
            req.home = v.rid
            req.dispatched = False
        req.state = "placed"
        req.owner = v.rid
        req.placements += 1
        self._pending.remove(req.rid)
        v.backlog_seconds += v.prices.get(level, 0.0)
        return v.rid

    def bind(self, req: FleetRequest, engine_id: int) -> None:
        req.engine_id = engine_id

    # ------------------------------------------------------------------
    # Drain / death / completion

    def handback(self, req: FleetRequest, *, lost_state: bool) -> None:
        """Return a placed request to the pending pool. ``lost_state``
        distinguishes a death re-admission (cache slots gone, forced
        refresh ahead) from a drain handback of a never-dispatched
        request (free to move)."""
        if req.state == "done":
            return
        req.state = "pending"
        req.owner = -1
        req.engine_id = -1
        if lost_state:
            req.readmits += 1
        else:
            req.handbacks += 1
            if not req.dispatched:
                req.home = -1     # no state anywhere: next home is free
        self.handbacks += 1
        self._pending.append(req.rid)

    def mark_done(self, req: FleetRequest, now: float,
                  served_by: int) -> bool:
        """First completion wins (a hedged twin may land later); returns
        False for the loser so the caller drops the duplicate."""
        if req.state == "done":
            return False
        if req.rid in self._pending:
            # a hedged twin can win while the original sits re-admitted
            # (e.g. quarantine escalation backoff): drop it from the pool
            self._pending.remove(req.rid)
        req.state = "done"
        req.done_at = now
        req.served_by = served_by
        return True

    def escalate(self, req: FleetRequest, *, now: float, level: float,
                 max_retries: int = 2,
                 backoff_base: float = 0.05) -> bool:
        """Re-admit a quarantined (non-finite) request at the most
        powerful menu ``level`` — weak→powerful escalation. The same key
        restarts the trajectory from step 0, so the recovered sample is
        exactly the clean powerful-path sample. Backoff doubles per
        retry and is capped at a quarter of the remaining deadline slack
        so escalation never *causes* the expiry it is racing. A request
        is never dropped: past ``max_retries`` it still re-enqueues (at
        the capped backoff) but the overflow is counted and False
        returned so the caller can alarm."""
        if req.state == "done":
            return False
        self.handback(req, lost_state=True)
        req.budget = float(level)
        req.retries += 1
        req.escalated = True
        self.escalations += 1
        n = min(req.retries, max(1, max_retries))
        backoff = backoff_base * (2.0 ** (n - 1))
        if math.isfinite(req.deadline):
            backoff = min(backoff, max(0.0, (req.deadline - now) * 0.25))
        req.not_before = now + backoff
        if req.retries > max_retries:
            self.escalation_overflows += 1
            return False
        return True

    def mark_expired(self, req: FleetRequest, now: float) -> bool:
        """Terminal deadline expiry: the request leaves the unfinished
        set without a result (counted, journaled, never silently lost)."""
        if req.state in ("done", "expired"):
            return False
        if req.rid in self._pending:
            self._pending.remove(req.rid)
        req.state = "expired"
        req.owner = -1
        req.engine_id = -1
        req.done_at = now
        self.expirations += 1
        return True

    def mark_hedged(self, req: FleetRequest, replica: int,
                    engine_id: int) -> None:
        req.hedged = True
        req.hedge_owner = replica
        req.hedge_engine_id = engine_id
        self.hedges += 1

    # ------------------------------------------------------------------

    def affinity_hit_rate(self, total_request_dispatches: int) -> float:
        """1 - state-losing re-admissions / request-dispatches (every
        dispatch runs on the replica holding the request's slots except
        the forced-refresh one right after a state-losing move)."""
        if total_request_dispatches <= 0:
            return 1.0
        return 1.0 - min(self.state_readmits,
                         total_request_dispatches) / total_request_dispatches

    def summary(self) -> Dict[str, object]:
        return {
            "policy": self.policy,
            "registered": float(self._next_id),
            "pending": float(len(self._pending)),
            "placements": float(self.placements),
            "affine_placements": float(self.affine_placements),
            "state_readmits": float(self.state_readmits),
            "handbacks": float(self.handbacks),
            "hedges": float(self.hedges),
            "hedge_wins": float(self.hedge_wins),
            "escalations": float(self.escalations),
            "escalation_overflows": float(self.escalation_overflows),
            "expirations": float(self.expirations),
        }
