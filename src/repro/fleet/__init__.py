"""Fleet serving: a replica router in front of per-replica engines
(DESIGN.md §fleet).

Control plane (host-pure, linted): ``router`` (placement + affinity
ledger), ``membership`` (heartbeat drain/join/death), ``health``
(straggler weights + hedging). Data plane: ``replica`` (engine + clock
+ price), ``fleet`` (the front door), ``warmup`` (background warm-set
compilation).
"""
from repro.fleet.fleet import Fleet, FleetResult
from repro.fleet.health import FleetHealth
from repro.fleet.membership import (FleetMembership, ProcessGroup,
                                    init_process_group, partition_devices)
from repro.fleet.replica import FixedSlotEngine, Replica, ReplicaClock
from repro.fleet.router import (ROUTER_POLICIES, FleetRequest,
                                ReplicaView, Router)
from repro.fleet.warmup import BackgroundCompiler

__all__ = [
    "Fleet", "FleetResult", "FleetHealth", "FleetMembership",
    "ProcessGroup", "init_process_group", "partition_devices",
    "FixedSlotEngine", "Replica", "ReplicaClock", "ROUTER_POLICIES",
    "FleetRequest", "ReplicaView", "Router", "BackgroundCompiler",
]
