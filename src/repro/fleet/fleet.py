"""The fleet front door (DESIGN.md §fleet).

``Fleet`` runs one serving engine per data-parallel replica behind a
single submit/tick surface and glues the three control modules
together: :class:`~repro.fleet.router.Router` decides placement,
:class:`~repro.fleet.membership.FleetMembership` tracks
drain/join/death over heartbeats, and
:class:`~repro.fleet.health.FleetHealth` down-weights stragglers and
picks hedge candidates. The driver loop is ``tick()``:

1. **place** every routable pending request (scored by priced backlog +
   per-level calibrated price x straggler weight; see router.py);
2. **pump** each live replica one engine iteration — a pump is also the
   replica's heartbeat, so a hung replica stops beating and the monitor
   declares it dead after the timeout;
3. **retire** finished drains (in-flight cohort emptied);
4. **detect** deaths and re-admit the dead replica's
   accepted-but-unfinished requests elsewhere (same PRNG key → restart
   from step 0 reproduces the uninterrupted sample; fresh slot
   allocation on the new replica forces the cache refresh);
5. **hedge** deadline-critical requests predicted late on a slow
   replica (first completion wins, the twin is cancelled if still
   queued, dropped at completion otherwise).

Time: with the default wall clock every engine shares
``time.monotonic``. With an injected simulated clock the fleet runs in
*virtual time* — each replica's clock advances by modeled dispatch cost
(replica.py) — which is how a one-accelerator container demonstrates
N-replica aggregate throughput honestly; see DESIGN.md §fleet for what
transfers to real multi-host.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.fleet.health import FleetHealth
from repro.fleet.membership import FleetMembership, init_process_group
from repro.fleet.replica import (DEFAULT_SECONDS_PER_TOKEN, Replica,
                                 ReplicaClock)
from repro.fleet.router import FleetRequest, ReplicaView, Router
from repro.fleet.warmup import BackgroundCompiler
from repro.pipeline.pipeline import FlexiPipeline
from repro.pipeline.plan import SamplingPlan
from repro.resilience.faults import (ALLOC_FAIL, CORRUPT_SLOT, CRASH,
                                     HANG, HEARTBEAT_DELAY, PARTITION,
                                     POISON, SLOWDOWN, UNHANG,
                                     FaultInjector, FaultPlan)
from repro.resilience.journal import RequestJournal
from repro.serving.metrics import RequestRecord
from repro.serving.scheduler import ServedResult
from repro.telemetry import Telemetry


@dataclasses.dataclass
class FleetResult:
    """One served request, fleet view."""
    rid: int
    cond: int
    x0: jax.Array
    budget_served: float
    replica: int
    record: RequestRecord
    arrival: float
    done_at: float

    @property
    def latency(self) -> float:
        return self.done_at - self.arrival


class Fleet:
    """N replica engines behind one router.

    >>> fleet = Fleet(pipe, plans, n_replicas=4, clock=FakeClock())
    >>> fleet.submit(cond=3, budget=0.6)
    >>> results = fleet.run()
    """

    def __init__(self, pipe: FlexiPipeline,
                 plans: Dict[float, SamplingPlan],
                 n_replicas: int, *,
                 router: str = "cheapest",
                 clock: Optional[Callable[[], float]] = None,
                 virtual: Optional[bool] = None,
                 seconds_per_token: float = DEFAULT_SECONDS_PER_TOKEN,
                 speed_factors: Optional[Dict[int, float]] = None,
                 heartbeat_timeout_s: float = 10.0,
                 telemetry: Optional[Telemetry] = None,
                 base_key: Optional[jax.Array] = None,
                 engine_kind: str = "packed",
                 batch_size: int = 4,
                 pipes: Optional[Sequence[FlexiPipeline]] = None,
                 device_ids: Optional[Sequence[int]] = None,
                 seq_parallel: int = 1,
                 process_group=None,
                 warm_background: bool = False,
                 faults: Optional[FaultPlan] = None,
                 journal: Optional[RequestJournal] = None,
                 expire_queued: bool = False,
                 max_retries: int = 2,
                 backoff_base_s: float = 0.05,
                 engine_kwargs: Optional[Dict[str, Any]] = None):
        if n_replicas < 1:
            raise ValueError("a fleet needs at least one replica")
        self._clock = clock or time.monotonic
        # resilience (DESIGN.md §resilience): a scripted FaultPlan arms
        # the injection seams; with faults=None every seam is a no-op
        # and the hot path is byte-for-byte the pre-resilience code
        self._injector = (FaultInjector(faults)
                          if faults is not None else None)
        self._journal = journal
        self._expire_queued = bool(expire_queued)
        self._max_retries = int(max_retries)
        self._backoff_base = float(backoff_base_s)
        self._escalate_pending: Dict[int, float] = {}
        self.escalation_latencies: List[float] = []
        # a caller-injected clock means simulated time (tests, benches)
        # unless explicitly overridden; wall serving passes no clock
        self.virtual = virtual if virtual is not None else clock is not None
        self.plans = plans
        self.group = (process_group if process_group is not None
                      else init_process_group())
        if device_ids is None:
            device_ids = list(range(n_replicas * seq_parallel))
        self.membership = FleetMembership(
            n_replicas, device_ids, seq_parallel=seq_parallel,
            timeout_s=heartbeat_timeout_s, clock=self._clock)
        self.health = FleetHealth(n_replicas)
        self.router = Router(router)
        self.telemetry = telemetry
        self._rec = telemetry.recorder if telemetry is not None else None
        if telemetry is not None:
            telemetry.bind_clock(self._clock)
        self._base_key = (base_key if base_key is not None
                          else jax.random.PRNGKey(0xf1ee))
        self._spt = seconds_per_token
        self._engine_kind = engine_kind
        self._batch_size = batch_size
        self._engine_kwargs = dict(engine_kwargs or {})
        speed_factors = speed_factors or {}
        if pipes is not None and len(pipes) != n_replicas:
            raise ValueError(f"pipes: got {len(pipes)} for "
                             f"{n_replicas} replicas")
        self._default_pipe = pipe
        self.replicas: Dict[int, Replica] = {}
        for i in range(n_replicas):
            self.replicas[i] = self._build_replica(
                i, pipes[i] if pipes is not None else pipe,
                speed_factors.get(i, 1.0))
        # (replica id, engine-local request id) -> fleet request id
        self._emap: Dict[Tuple[int, int], int] = {}
        self.results: Dict[int, FleetResult] = {}
        self._hung: set = set()           # fault injection: stop pumping
        self._death_pending: Dict[int, float] = {}
        self.readmit_latencies: List[float] = []
        self._hedge_losses = 0
        self._t0 = self._clock()
        self.warmers: Dict[int, BackgroundCompiler] = {}
        if warm_background:
            for i, rep in self.replicas.items():
                if self._engine_kind == "packed":
                    self.warmers[i] = BackgroundCompiler(
                        rep.engine, name=f"fleet-warm-r{i}").start()

    def _build_replica(self, rid: int, pipe: FlexiPipeline,
                       speed_factor: float) -> Replica:
        kw = dict(self._engine_kwargs)
        faults = None
        if self._injector is not None:
            faults = self._injector.for_replica(rid)
            # engines park quarantined requests for the router (fleet
            # owns escalation) and checksum their cache slots so the
            # corruption seam is detectable
            kw["faults"] = faults
            kw["self_heal"] = False
            kw.setdefault("cache_integrity", True)
        if self._expire_queued and self._engine_kind == "packed":
            kw["expire_queued"] = True
        return Replica(rid, pipe, self.plans,
                       engine_kind=self._engine_kind,
                       virtual=self.virtual,
                       seconds_per_token=self._spt,
                       speed_factor=speed_factor,
                       clock=self._clock,
                       batch_size=self._batch_size,
                       faults=faults,
                       engine_kwargs=kw)

    # ------------------------------------------------------------------
    # Submission

    @property
    def now(self) -> float:
        return self._clock()

    def submit(self, cond: int, budget: float,
               deadline: float = math.inf,
               key: Optional[jax.Array] = None) -> int:
        """Accept one request into the fleet; returns its fleet id. The
        key (derived from the fleet id when absent) pins the sample: any
        replica — including a post-kill re-admission target — produces
        the identical latents."""
        rid = self.router._next_id
        if key is None:
            key = jax.random.fold_in(self._base_key, rid)
        now = self.now
        if self._journal is not None:
            # write-ahead: the admit record lands on disk BEFORE the
            # router ledger accepts the request, so a crash after this
            # line can replay it and a crash before it never saw it
            self._journal.admit(rid, cond=int(cond), budget=float(budget),
                                deadline=float(deadline), time=now)
        req = self.router.register(cond, budget, deadline, key, now)
        return req.rid

    # ------------------------------------------------------------------
    # Placement

    def _views(self) -> List[ReplicaView]:
        weights = self.health.weights()
        views = []
        for rid, rep in self.replicas.items():
            views.append(ReplicaView(
                rid=rid,
                admitting=(self.membership.admitting(rid)
                           and rid not in self._hung),
                backlog_seconds=rep.backlog_seconds(),
                prices=rep.prices(),
                weight=weights.get(rid, 1.0)))
        return views

    def _place_pending(self, now: float) -> int:
        pending = self.router.pending(now)
        if not pending:
            return 0
        views = self._views()
        if not any(v.admitting for v in views):
            return 0                  # wait for a join/rejoin
        t0 = now
        placed = 0
        for req in pending:
            level = self._quantize(req.budget)
            target = self.router.place(req, views, level)
            rep = self.replicas[target]
            if self.virtual:
                rep.rclock.catch_up(now)
            eid = rep.submit(req.cond, req.budget, req.deadline, req.key)
            self.router.bind(req, eid)
            self._emap[(target, eid)] = req.rid
            placed += 1
            if self._journal is not None:
                self._journal.dispatch(req.rid, replica=target, time=now)
            if req.rid in self._death_pending:
                self.readmit_latencies.append(
                    now - self._death_pending.pop(req.rid))
        if self._rec is not None and placed:
            self._rec.complete("route", t0, self.now,
                               args={"placed": placed,
                                     "policy": self.router.policy})
        return placed

    def _quantize(self, budget: float) -> float:
        return next(iter(self.replicas.values())).engine.quantize(budget)

    # ------------------------------------------------------------------
    # The driver loop

    def tick(self) -> List[FleetResult]:
        """One scheduling round; returns requests finished this round."""
        now = self.now
        out: List[FleetResult] = []
        if self._injector is not None:
            self._apply_faults(now)
        self._place_pending(now)
        for rid, rep in sorted(self.replicas.items()):
            if not self.membership.pumpable(rid) or rid in self._hung:
                continue
            if rep.has_work:
                results, dt = rep.pump(now)
                if dt > 0:
                    self.health.record_dispatch(rid, dt * 1e3)
                for f in getattr(rep.engine, "_inflight", ()):
                    frid = self._emap.get((rid, f.req.id))
                    if frid is not None:
                        self.router.requests[frid].dispatched = True
                for sr in results:
                    r = self._finish(rid, sr)
                    if r is not None:
                        out.append(r)
            self._intake_recovery(rid, rep, now)
            # pumping (even an idle pass) is the in-process heartbeat;
            # an armed injector may drop (partition) or hold (skew) it
            if self._injector is not None:
                stamp = self._injector.route_beat(rid, now)
                if stamp is not None:
                    self.membership.beat(rid, at=stamp)
            else:
                self.membership.beat(rid)
        if self._injector is not None:
            # delayed heartbeats arrive late with their ORIGINAL stamp —
            # the monitor's max() guard keeps them from rewinding
            for brid, stamp in self._injector.due_beats(now):
                self.membership.beat(brid, at=stamp)
        for rid in list(self.replicas):
            if self.membership.state(rid) == "draining" \
                    and self.replicas[rid].engine.idle:
                self.membership.finish_drain(rid)
        for rid in self.membership.check():
            self._on_death(rid)
        self._maybe_hedge(self.now)
        if self._rec is not None:
            self._rec.counter("fleet", {
                "pending": self.router.n_pending,
                **{f"r{rid}_inflight": self.replicas[rid].engine.n_inflight
                   for rid in sorted(self.replicas)},
                **{f"r{rid}_queued": self.replicas[rid].engine.n_queued
                   for rid in sorted(self.replicas)}})
        return out

    def run(self, max_ticks: int = 100_000) -> List[FleetResult]:
        """Drain: tick until every accepted request is served."""
        out: List[FleetResult] = []
        ticks = 0
        while self.router.unfinished() and ticks < max_ticks:
            out.extend(self.tick())
            ticks += 1
            if self.router.unfinished() and self.membership.alive_count == 0:
                raise RuntimeError("fleet has no live replicas but "
                                   f"{len(self.router.unfinished())} "
                                   "unfinished requests")
            self._advance_past_backoff()
        return out

    def _advance_past_backoff(self) -> None:
        """With a simulated clock, time only moves when a replica pumps
        work — so if every unfinished request sits in an escalation
        backoff window and every live replica is idle, the clock must be
        advanced to the earliest ``not_before`` or ``run`` spins
        forever. No-op on wall clocks (time passes by itself) and
        whenever any replica still has work."""
        held = [r.not_before for r in self.router.requests.values()
                if r.state == "pending" and r.not_before > self.now]
        if not held or not hasattr(self._clock, "advance"):
            return
        if self.router.pending(self.now):
            return                    # something is routable right now
        for rid, rep in self.replicas.items():
            if self.membership.pumpable(rid) and rid not in self._hung \
                    and rep.has_work:
                return
        self._clock.advance(min(held) - self.now + 1e-9)

    def _finish(self, rid: int, sr: ServedResult) -> Optional[FleetResult]:
        frid = self._emap.pop((rid, sr.request.id), None)
        if frid is None:
            return None               # stale (pre-death incarnation)
        req = self.router.requests[frid]
        now = (self.replicas[rid].rclock() if self.virtual else self.now)
        if not self.router.mark_done(req, now, rid):
            self._hedge_losses += 1   # the twin won earlier
            return None
        if self._journal is not None:
            self._journal.finish(frid, replica=rid, time=now)
        if frid in self._escalate_pending:
            # fleet-clock on both ends (the quarantine intake stamped
            # fleet time; replica virtual clocks run on another scale)
            self.escalation_latencies.append(
                self.now - self._escalate_pending.pop(frid))
        req.dispatched = True
        if req.hedged:
            if rid == req.hedge_owner:
                self.router.hedge_wins += 1
            self._cancel_copy(req, winner=rid)
        res = FleetResult(rid=frid, cond=req.cond, x0=sr.x0,
                          budget_served=sr.budget_served, replica=rid,
                          record=sr.record, arrival=req.arrival,
                          done_at=now)
        self.results[frid] = res
        return res

    # ------------------------------------------------------------------
    # Resilience (DESIGN.md §resilience)

    def _apply_faults(self, now: float) -> None:
        """Pop due scripted fault events and apply each at its seam.
        Events whose target is not actionable yet (a poison for a not
        yet placed request, a corruption with no resident slot) are
        deferred and retried next tick."""
        inj = self._injector
        if inj is None:
            return
        for ev in inj.due(now):
            if ev.kind == CRASH:
                if self.membership.state(ev.replica) in ("active",
                                                         "draining"):
                    self.kill_replica(ev.replica)
            elif ev.kind == HANG:
                self.inject_hang(ev.replica)
            elif ev.kind == UNHANG:
                self._hung.discard(ev.replica)
            elif ev.kind == HEARTBEAT_DELAY:
                inj.delay_beats(ev.replica, now + ev.duration, ev.delay)
            elif ev.kind == PARTITION:
                inj.partition(ev.replica, now + ev.duration)
            elif ev.kind == SLOWDOWN:
                inj.slow(ev.replica, now + ev.duration, ev.factor)
            elif ev.kind == POISON:
                req = self.router.requests.get(ev.rid)
                if req is None or req.state == "pending":
                    inj.defer(ev)     # not placed yet: retry next tick
                elif req.state == "placed":
                    inj.add_poison(req.owner, req.engine_id)
                # done/expired: nothing left to poison — event dropped
            elif ev.kind == CORRUPT_SLOT:
                engine = self.replicas[ev.replica].engine
                store = getattr(engine, "store", None)
                slots = store.active_slots() if store is not None else []
                if not slots:
                    inj.defer(ev)     # nothing resident yet
                else:
                    # prefer a slot whose owner still has same-mode
                    # steps ahead (it re-packs this slot, so the
                    # checksum mismatch is actually observed instead of
                    # the slot being released at a phase switch or
                    # retire first) and is not itself marked for
                    # poisoning (quarantine would release the slot
                    # unverified); fall back to a seeded random pick
                    best, best_rem = None, 0
                    for f in getattr(engine, "_inflight", ()):
                        if (f.cache_slot >= 0 and not f.done
                                and int(f.lp.modes[f.step]) == f.cache_mode
                                and not inj.is_poison_target(ev.replica,
                                                             f.req.id)
                                and store.owner_of(
                                    f.cache_mode,
                                    f.cache_slot) == f.req.id):
                            rem = int(f.lp.run_len[f.step])
                            if rem > best_rem:
                                best = (f.cache_mode, f.cache_slot)
                                best_rem = rem
                    mode, slot = (best if best is not None
                                  else slots[inj.rng.randrange(
                                      len(slots))])
                    store.corrupt_slot(mode, slot)
                    inj.note_corruption()
            elif ev.kind == ALLOC_FAIL:
                inj.add_alloc_failures(ev.replica, ev.count)

    def _intake_recovery(self, rid: int, rep: Replica, now: float) -> None:
        """Drain one engine's quarantined/expired request pools into
        fleet-level recovery: quarantined requests escalate (re-admit at
        the most powerful level, deadline-aware backoff), expired ones
        turn terminal. Both paths journal."""
        eng = rep.engine
        take_q = getattr(eng, "take_quarantined", None)
        if take_q is not None:
            for r in take_q():
                frid = self._emap.pop((rid, r.id), None)
                if frid is None:
                    continue
                fr = self.router.requests[frid]
                self.router.escalate(
                    fr, now=now, level=max(rep._levels),
                    max_retries=self._max_retries,
                    backoff_base=self._backoff_base)
                self._escalate_pending.setdefault(frid, now)
                if self._journal is not None:
                    self._journal.escalate(frid, time=now,
                                           retries=fr.retries)
                if self._rec is not None:
                    self._rec.instant("escalate",
                                      args={"rid": frid, "replica": rid,
                                            "retries": fr.retries})
        take_e = getattr(eng, "take_expired", None)
        if take_e is not None:
            for r in take_e():
                frid = self._emap.pop((rid, r.id), None)
                if frid is None:
                    continue
                if self.router.mark_expired(self.router.requests[frid],
                                            now) \
                        and self._journal is not None:
                    self._journal.expire(frid, time=now)

    def resubmit_from_journal(self, journal: RequestJournal) -> List[int]:
        """Exactly-once replay after a front-door crash: re-admit every
        journaled request without a terminal record. Keys re-derive from
        the journaled fleet rid (``fold_in(base_key, rid)``), so a
        replayed request reproduces the latents the lost router would
        have served. This fleet must share the crashed fleet's
        ``base_key``. Returns the new fleet ids, in original admission
        order."""
        out: List[int] = []
        for rec in journal.unfinished():
            key = jax.random.fold_in(self._base_key, int(rec["rid"]))
            out.append(self.submit(int(rec["cond"]), float(rec["budget"]),
                                   deadline=math.inf, key=key))
        return out

    # ------------------------------------------------------------------
    # Drain / join / death

    def drain_replica(self, rid: int) -> int:
        """Stop admissions on ``rid``, hand its queued requests back to
        the router (they re-place immediately), let the in-flight cohort
        finish on subsequent ticks. Returns how many were handed back."""
        self.membership.start_drain(rid)
        eng = self.replicas[rid].engine
        eng.stop_admissions()
        handed = 0
        for r in eng.extract_queued():
            frid = self._emap.pop((rid, r.id), None)
            if frid is None:
                continue
            self.router.handback(self.router.requests[frid],
                                 lost_state=False)
            handed += 1
        if self._rec is not None:
            self._rec.complete("drain", self.now, self.now,
                               args={"replica": rid, "handed_back": handed})
        self._place_pending(self.now)
        return handed

    def kill_replica(self, rid: int) -> int:
        """Crash ``rid`` now (observed failure): everything it accepted
        and hadn't finished is re-admitted elsewhere. Returns the count
        of re-admitted requests."""
        self.membership.mark_dead(rid)
        return self._on_death(rid)

    def inject_hang(self, rid: int) -> None:
        """Fault injection: the replica stops being pumped (so stops
        heartbeating); membership declares it dead after the timeout."""
        self._hung.add(rid)

    def rejoin_replica(self, rid: int, *,
                       speed_factor: float = 1.0) -> int:
        """Bring a dead/drained replica id back with a FRESH engine (the
        old incarnation's state is untrusted); returns the incarnation."""
        inc = self.membership.rejoin(rid)
        self._hung.discard(rid)
        self.replicas[rid] = self._build_replica(
            rid, self._default_pipe, speed_factor)
        if self.virtual:
            self.replicas[rid].rclock.catch_up(self.now)
        return inc

    def join_replica(self, *, device_ids: Optional[Sequence[int]] = None,
                     speed_factor: float = 1.0,
                     warm_background: bool = False) -> int:
        """Grow the fleet by one replica; optionally warm its ladder on
        a background thread while it already takes traffic."""
        if device_ids is None:
            hi = max((max(i.device_ids) for i in
                      self.membership.replicas.values()), default=-1)
            device_ids = list(range(hi + 1,
                                    hi + 1 + self.membership.seq_parallel))
        rid = self.membership.join(device_ids)
        self.health.grow(rid + 1)
        self.replicas[rid] = self._build_replica(
            rid, self._default_pipe, speed_factor)
        if self.virtual:
            self.replicas[rid].rclock.catch_up(self.now)
        if warm_background and self._engine_kind == "packed":
            self.warmers[rid] = BackgroundCompiler(
                self.replicas[rid].engine,
                name=f"fleet-warm-r{rid}").start()
        return rid

    def _on_death(self, rid: int) -> int:
        now = self.now
        orphans = [r for r in self.router.requests.values()
                   if r.state == "placed" and r.owner == rid]
        for req in orphans:
            self._emap.pop((rid, req.engine_id), None)
            self.router.handback(req, lost_state=req.dispatched)
            self._death_pending[req.rid] = now
        # a dead replica's hedge COPIES die with it; the originals live
        for req in self.router.requests.values():
            if req.hedged and req.hedge_owner == rid:
                self._emap.pop((rid, req.hedge_engine_id), None)
                req.hedged = False
                req.hedge_owner = req.hedge_engine_id = -1
        if self._rec is not None:
            self._rec.complete("readmit", now, self.now,
                               args={"replica": rid,
                                     "orphans": len(orphans)})
        self._place_pending(self.now)
        return len(orphans)

    # ------------------------------------------------------------------
    # Hedging

    def _maybe_hedge(self, now: float) -> None:
        cands: List[FleetRequest] = []
        lateness: List[float] = []
        weights = self.health.weights()
        for req in self.router.requests.values():
            if (req.state != "placed" or req.hedged
                    or not math.isfinite(req.deadline)):
                continue
            if weights.get(req.owner, 1.0) <= 1.5:
                continue              # owner is healthy; don't double-spend
            est = self.replicas[req.owner].estimated_finish(
                req.engine_id, now)
            if est is None:
                continue
            cands.append(req)
            lateness.append((est - req.deadline) * 1e3)
        if not cands:
            return
        picked = self.health.hedge_candidates(
            [r.rid for r in cands], lateness)
        if not picked:
            return
        by_rid = {r.rid: r for r in cands}
        views = [v for v in self._views() if v.admitting]
        for rid in picked:
            req = by_rid[rid]
            targets = [v for v in views if v.rid != req.owner]
            if not targets:
                continue
            best = min(targets, key=lambda v: (v.weight, v.score(
                self._quantize(req.budget)), v.rid))
            rep = self.replicas[best.rid]
            if self.virtual:
                rep.rclock.catch_up(now)
            eid = rep.submit(req.cond, req.budget, req.deadline, req.key)
            self._emap[(best.rid, eid)] = req.rid
            self.router.mark_hedged(req, best.rid, eid)
            if self._rec is not None:
                self._rec.complete("hedge", now, self.now,
                                   args={"rid": req.rid,
                                         "from": req.owner,
                                         "to": best.rid})

    def _cancel_copy(self, req: FleetRequest, winner: int) -> None:
        """Drop the losing copy of a hedged request if it is still only
        queued (in-flight copies run to completion and are dropped at
        finish by first-wins)."""
        loser, eid = ((req.hedge_owner, req.hedge_engine_id)
                      if winner != req.hedge_owner
                      else (req.owner, req.engine_id))
        if loser < 0 or loser not in self.replicas:
            return
        eng = self.replicas[loser].engine
        for r in list(eng._queue._pending):
            if r.id == eid:
                eng._queue._pending.remove(r)
                self._emap.pop((loser, eid), None)
                break

    # ------------------------------------------------------------------
    # Warm-set

    def precapture(self, max_per_mode: int = 2) -> int:
        """Synchronous warm-set capture on every packed replica (shared
        pipelines make replicas after the first free)."""
        n = 0
        for rep in self.replicas.values():
            if self._engine_kind == "packed":
                n += rep.engine.precapture_warm_set(max_per_mode)
        return n

    def wait_warm(self, timeout: Optional[float] = None) -> None:
        """Join every background compiler and prove the ladders warm."""
        for w in self.warmers.values():
            if not w.wait(timeout):
                raise TimeoutError("background warm-set capture still "
                                   "running")
            w.assert_warm()

    # ------------------------------------------------------------------
    # Introspection

    def compile_stats(self) -> Dict[str, int]:
        """Aggregated compile counters over the DISTINCT pipelines the
        replicas use (shared pipelines count once — one XLA process)."""
        seen: Dict[int, Dict[str, int]] = {}
        for rep in self.replicas.values():
            p = rep.engine.pipe
            seen[id(p)] = p.cache_stats()
        agg = {"pipes": len(seen), "runners": 0, "hits": 0, "misses": 0,
               "compiled": 0}
        for st in seen.values():
            for k in ("runners", "hits", "misses", "compiled"):
                agg[k] += st[k]
        return agg

    def makespan(self) -> float:
        if self.virtual:
            clocks = [rep.rclock() for rep in self.replicas.values()
                      if isinstance(rep.rclock, ReplicaClock)]
            return (max(clocks) if clocks else self.now) - self._t0
        return self.now - self._t0

    def summary(self) -> Dict[str, Any]:
        tokens = sum(r.record.tokens for r in self.results.values())
        makespan = self.makespan()
        dispatches = sum(
            rep.engine.metrics.total_request_steps
            for rep in self.replicas.values())
        rep_report = self.health.report()
        out: Dict[str, Any] = {
            "replicas": len(self.replicas),
            "served": len(self.results),
            "tokens": float(tokens),
            "makespan_s": makespan,
            "tokens_per_s": tokens / makespan if makespan > 0 else 0.0,
            "request_dispatches": float(dispatches),
            "affinity_hit_rate":
                self.router.affinity_hit_rate(dispatches),
            "router": self.router.summary(),
            "membership": self.membership.summary(),
            "straggler": {"stragglers": list(rep_report.stragglers),
                          "median_ms": rep_report.median_ms,
                          "worst_ms": rep_report.worst_ms},
            "readmit": {
                "count": float(len(self.readmit_latencies)),
                "mean_s": (sum(self.readmit_latencies)
                           / len(self.readmit_latencies)
                           if self.readmit_latencies else 0.0),
                "max_s": (max(self.readmit_latencies)
                          if self.readmit_latencies else 0.0)},
            "hedge_losses": float(self._hedge_losses),
            "escalation": {
                "count": float(len(self.escalation_latencies)),
                "outstanding": float(len(self._escalate_pending)),
                "mean_s": (sum(self.escalation_latencies)
                           / len(self.escalation_latencies)
                           if self.escalation_latencies else 0.0),
                "max_s": (max(self.escalation_latencies)
                          if self.escalation_latencies else 0.0)},
            "compile": self.compile_stats(),
            "per_replica": {
                str(rid): rep.engine.metrics.summary()
                for rid, rep in sorted(self.replicas.items())},
        }
        if self._injector is not None:
            out["faults"] = self._injector.summary()
        if self._journal is not None:
            out["journal"] = self._journal.summary()
        return out
