"""Fleet health: straggler down-weighting + hedged re-dispatch — HOST-PURE.

Wires :mod:`repro.runtime.straggler` into routing. Every pump of a
replica engine reports its dispatch wall time (virtual or measured
milliseconds) to a :class:`StragglerDetector`; routing then multiplies
each replica's placement score by ``weight = clamp(ewma / median, 1,
max_weight)`` so persistently slow replicas receive proportionally less
new work — smooth degradation, with the detector's ``threshold x
median`` flag reserved for the health report.

Hedging: for deadline-critical requests stuck on a slow replica the
fleet computes a *lateness* estimate (predicted finish minus deadline)
and :func:`runtime.straggler.backup_request_schedule` picks which ones
get a backup copy submitted to the fastest admitting replica — same
PRNG key, so whichever copy lands first yields the identical sample and
the loser is dropped at completion (first-wins dedup in the router).

This module does the *policy* arithmetic only; the numpy-backed EWMA
lives in ``runtime.straggler`` (host arrays, no device work). Like the
other fleet control modules it must pass the ``fleet-host-pure`` lint:
no jax/numpy imports, no device syncs.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from repro.runtime.straggler import (StragglerDetector, StragglerReport,
                                     backup_request_schedule)


class FleetHealth:
    """Per-replica dispatch-time EWMA -> routing weights + hedge picks."""

    def __init__(self, n_replicas: int, *, threshold: float = 2.0,
                 ewma: float = 0.7, max_weight: float = 4.0):
        self.detector = StragglerDetector(n_replicas, threshold=threshold,
                                          ewma=ewma)
        self.max_weight = max_weight
        self._ticks = 0

    def grow(self, n_replicas: int) -> None:
        """Widen to ``n_replicas`` (a joined replica starts unseen —
        weight 1.0 until it reports)."""
        if n_replicas <= self.detector.n:
            return
        old = self.detector
        new = StragglerDetector(n_replicas, threshold=old.threshold,
                                ewma=old.ewma)
        for i in range(old.n):
            if old.seen[i]:
                new.times[i] = old.times[i]
                new.seen[i] = True
        self.detector = new

    def record_dispatch(self, rid: int, wall_ms: float) -> None:
        self.detector.record(rid, wall_ms)

    def report(self) -> StragglerReport:
        self._ticks += 1
        return self.detector.report(self._ticks)

    def weights(self) -> Dict[int, float]:
        """Routing multiplier per replica: EWMA time over the fleet
        median, clamped to [1, max_weight]. Unseen replicas (just
        joined, never dispatched) route at 1.0."""
        rep = self.detector.report(self._ticks)
        out: Dict[int, float] = {}
        for i in range(self.detector.n):
            w = 1.0
            if self.detector.seen[i] and rep.median_ms > 0:
                w = min(max(float(self.detector.times[i]) / rep.median_ms,
                            1.0), self.max_weight)
            out[i] = w
        return out

    def ewma_ms(self, rid: int) -> float:
        """This replica's smoothed dispatch wall (0.0 before any
        report) — the fleet's per-request finish predictor."""
        if rid < self.detector.n and self.detector.seen[rid]:
            return float(self.detector.times[rid])
        return 0.0

    def hedge_candidates(self, request_ids: Sequence[int],
                         lateness_ms: Sequence[float]
                         ) -> List[int]:
        """Which of ``request_ids`` deserve a backup copy: exactly the
        seed hedged-request policy, applied to predicted lateness
        (``predicted_finish - deadline`` in ms; positive = will miss)."""
        if not request_ids:
            return []
        idx = backup_request_schedule(list(lateness_ms), 0.0)
        return [request_ids[i] for i in idx]
