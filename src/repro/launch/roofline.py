"""Roofline-term extraction from compiled dry-run artifacts.

Sources (per the assignment):
  * ``compiled.cost_analysis()`` → HLO FLOPs and bytes accessed. For an
    SPMD-partitioned executable these are **per-device** numbers.
  * ``compiled.as_text()`` → the partitioned HLO; we parse every
    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute and sum operand sizes.

Hardware model (TPU v5e-class, per chip):
  197 TFLOP/s bf16 · 819 GB/s HBM · ~50 GB/s/link ICI · 16 GiB HBM.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_BYTES = 16 * 1024 ** 3

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dtype, dims = m.group(1), m.group(2)
    bs = _DTYPE_BYTES.get(dtype)
    if bs is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * bs


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: op count, total operand bytes, total result bytes,
    and modeled wire bytes per device (ring algorithms)."""
    out = {k: {"count": 0, "operand_bytes": 0.0, "result_bytes": 0.0,
               "wire_bytes": 0.0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        kind = None
        for k in COLLECTIVES:
            token = f" {k}(" if f" {k}(" in stripped else (
                f" {k}-start(" if f" {k}-start(" in stripped else None)
            if token is not None:
                kind = k
                break
        if kind is None:
            continue
        # result shape(s): everything before ` = ` is the name; after it the
        # result shape, then `op(<operands>)`.
        try:
            lhs, rhs = stripped.split(" = ", 1)
        except ValueError:
            continue
        op_idx = rhs.find(kind)
        result_part = rhs[:op_idx]
        operand_part = rhs[op_idx:]
        res_bytes = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(result_part))
        opd_bytes = sum(_shape_bytes(m) for m in
                        _SHAPE_RE.finditer(operand_part.split("),", 1)[0]))
        if opd_bytes == 0:
            opd_bytes = res_bytes
        rec = out[kind]
        rec["count"] += 1
        rec["operand_bytes"] += opd_bytes
        rec["result_bytes"] += res_bytes
        # modeled bytes-on-wire per device (ring):
        if kind == "all-gather":
            rec["wire_bytes"] += max(res_bytes - opd_bytes, opd_bytes)
        elif kind == "all-reduce":
            rec["wire_bytes"] += 2 * opd_bytes
        else:
            rec["wire_bytes"] += opd_bytes
    return out


def roofline_terms(cost: Dict[str, float], collectives: Dict[str, Dict],
                   n_devices: int, model_flops_global: Optional[float] = None
                   ) -> Dict[str, Any]:
    flops_dev = float(cost.get("flops", 0.0) or 0.0)
    bytes_dev = float(cost.get("bytes accessed", 0.0) or 0.0)
    coll_operand = sum(v["operand_bytes"] for v in collectives.values())
    coll_wire = sum(v["wire_bytes"] for v in collectives.values())
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_wire / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll,
             "hlo_flops_per_device": flops_dev,
             "hlo_bytes_per_device": bytes_dev,
             "collective_operand_bytes": coll_operand,
             "collective_wire_bytes": coll_wire}
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom.replace("_s", "")
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction"] = (terms["compute_s"] / bound) if bound > 0 else 0.0
    if model_flops_global:
        terms["model_flops_global"] = model_flops_global
        hlo_global = flops_dev * n_devices
        terms["useful_flops_ratio"] = (model_flops_global / hlo_global
                                       if hlo_global else 0.0)
    return terms


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (6·N·D train / 2·N·D inference; active params for MoE)


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    n = cfg.active_params()
    if shape_kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens
