"""Abstract input specs (ShapeDtypeStruct + NamedSharding) for every
(architecture × shape) dry-run cell. No device allocation happens here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, ShapeConfig, TrainConfig,
                                cell_is_skipped, get_shape)
from repro.models import dit as dit_mod
from repro.models import lm
from repro.models.common import dtype_of, spec_tree
from repro.optim import adamw
from repro.runtime import sharding as shd

Params = Any

# Per-device activation budget used to pick gradient-accumulation depth.
ACT_BUDGET_BYTES = 3.0e9


def _sds(mesh: Mesh, shape, dtype, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def choose_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> int:
    n_dp = int(np.prod([s for a, s in zip(mesh.axis_names, mesh.devices.shape)
                        if a in ("pod", "data")]))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    per_dev = max(1, shape.global_batch // n_dp)
    act_per_sample = cfg.num_layers * shape.seq_len * cfg.d_model * 2
    if cfg.sequence_parallel:
        act_per_sample //= sizes.get("model", 1)
    n = int(np.ceil(per_dev * act_per_sample / ACT_BUDGET_BYTES))
    # microbatch count must divide per-device batch
    while per_dev % n != 0 and n < per_dev:
        n += 1
    return min(n, per_dev)


def abstract_params(cfg: ModelConfig, mesh: Mesh, profile: str = "fsdp2d"
                    ) -> Tuple[Params, Params]:
    """(ShapeDtypeStruct tree with shardings, PartitionSpec tree)."""
    rules = shd.rules_for(cfg, mesh, profile)
    sizes = shd.axis_sizes(mesh)
    if cfg.family == "dit":
        schema = dit_mod.dit_schema(cfg)
    else:
        schema = lm.lm_schema(cfg)
    specs = spec_tree(schema, rules, sizes)
    from repro.models.common import abstract_tree
    abstract = abstract_tree(schema, dtype_of(cfg.param_dtype))
    shaped = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                          sharding=NamedSharding(mesh, s)),
        abstract, specs)
    return shaped, specs


def abstract_opt_state(params_abs: Params, mesh: Mesh,
                       opt_dtype: jnp.dtype) -> Params:
    def mom(p):
        return jax.ShapeDtypeStruct(p.shape, opt_dtype, sharding=p.sharding)
    return {"m": jax.tree.map(mom, params_abs),
            "v": jax.tree.map(mom, params_abs),
            "step": jax.ShapeDtypeStruct(
                (), jnp.int32, sharding=NamedSharding(mesh, P()))}


def _extra_inputs(cfg: ModelConfig, B: int, mesh: Mesh, bspec: P
                  ) -> Dict[str, jax.ShapeDtypeStruct]:
    dt = dtype_of(cfg.compute_dtype)
    out = {}
    if cfg.family == "vlm":
        out["vision"] = _sds(mesh, (B, cfg.vision_tokens, cfg.d_model), dt,
                             P(bspec[0] if len(bspec) else None, None, None))
    if cfg.family == "audio":
        out["frames"] = _sds(mesh, (B, cfg.audio_frames, cfg.d_model), dt,
                             P(bspec[0] if len(bspec) else None, None, None))
    return out


def train_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                 ) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    bspec = shd.batch_spec(B, mesh)
    batch = {
        "tokens": _sds(mesh, (B, S), jnp.int32, P(*bspec, None)),
        "targets": _sds(mesh, (B, S), jnp.int32, P(*bspec, None)),
    }
    batch.update(_extra_inputs(cfg, B, mesh, bspec))
    return batch


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                   ) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    bspec = shd.batch_spec(B, mesh)
    inputs = {"tokens": _sds(mesh, (B, S), jnp.int32, P(*bspec, None))}
    inputs.update(_extra_inputs(cfg, B, mesh, bspec))
    return inputs


def cache_specs(cfg: ModelConfig, B: int, S: int, mesh: Mesh) -> Params:
    """Sharded ShapeDtypeStructs for the decode cache (context-parallel:
    sequence dim over the model axis; see DESIGN.md §5)."""
    b_ax, s_ax = shd.seq_axes_for_cache(B, mesh)
    abstract = lm.init_cache(cfg, B, S, abstract=True)
    out = {}
    for k, v in abstract.items():
        nd = len(v.shape)
        if k in ("k", "v"):
            if nd == 6:      # vlm self cache [G, k-1, B, S, K, hd]
                spec = P(None, None, b_ax, s_ax, None, None)
            else:            # [L, B, S, K, hd]
                spec = P(None, b_ax, s_ax, None, None)
        elif k in ("k_scale", "v_scale"):
            if nd == 5:      # vlm [G, k-1, B, S, K]
                spec = P(None, None, b_ax, s_ax, None)
            else:            # [L, B, S, K]
                spec = P(None, b_ax, s_ax, None)
        elif k in ("xk", "xv"):   # [G, B, Tv, K, hd]
            spec = P(None, b_ax, None, None, None)
        elif k == "enc":          # [B, F, d]
            spec = P(b_ax, None, None)
        elif k == "h":            # [L, B, H, P, N]
            spec = P(None, b_ax, None, None, None)
        elif k == "conv":         # [L, B, W-1, C]
            spec = P(None, b_ax, None, None)
        else:
            spec = P(*([None] * nd))
        out[k] = jax.ShapeDtypeStruct(v.shape, v.dtype,
                                      sharding=NamedSharding(mesh, spec))
    return out


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                  ) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    bspec = shd.batch_spec(B, mesh)
    return {
        "cache": cache_specs(cfg, B, S, mesh),
        "token": _sds(mesh, (B, 1), jnp.int32, P(*bspec, None)),
        "pos": _sds(mesh, (B,), jnp.int32, bspec),
    }


# ---------------------------------------------------------------------------
# DiT cells


DIT_SHAPES = {
    "dit-xl-2": {"train_base": 256, "serve_powerful": 32, "serve_weak": 32},
    "t2i-transformer": {"train_base": 64, "serve_powerful": 32, "serve_weak": 32},
    "video-dit": {"train_base": 8, "serve_powerful": 4, "serve_weak": 4},
}


def dit_inputs(cfg: ModelConfig, shape_name: str, mesh: Mesh
               ) -> Dict[str, Any]:
    B = DIT_SHAPES[cfg.name][shape_name]
    bspec = shd.batch_spec(B, mesh)
    dt = dtype_of(cfg.compute_dtype)
    F, H, W, C = cfg.dit.latent_shape
    x = _sds(mesh, (B, F, H, W, C), dt, P(*bspec, None, None, None, None))
    if cfg.dit.conditioning == "class":
        cond = _sds(mesh, (B,), jnp.int32, bspec)
        null = _sds(mesh, (B,), jnp.int32, bspec)
    else:
        dc = cfg.dit.text_dim or cfg.d_model
        cond = _sds(mesh, (B, cfg.dit.text_len, dc), dt, P(*bspec, None, None))
        null = _sds(mesh, (B, cfg.dit.text_len, dc), dt, P(*bspec, None, None))
    if shape_name == "train_base":
        return {"x0": x, "cond": cond,
                "key": jax.ShapeDtypeStruct((2,), jnp.uint32)}
    t = _sds(mesh, (B,), jnp.float32, bspec)
    return {"x_t": x, "t": t, "cond": cond, "null_cond": null}
