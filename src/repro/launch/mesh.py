"""Production mesh builders. Functions (not module constants) so importing
this module never touches jax device state."""
from __future__ import annotations

import os

import jax


def ensure_host_devices(n: int, env=None):
    """Force >= ``n`` fake host-platform devices for CPU smoke runs. Must
    run before jax's backend initializes. Appends unconditionally: XLA's
    flag parsing is last-one-wins, so a stale smaller count in XLA_FLAGS
    is overridden rather than silently kept. Harmless on real
    accelerators (the flag only affects the host platform)."""
    env = os.environ if env is None else env
    if n > 1:
        env["XLA_FLAGS"] = (
            f"{env.get('XLA_FLAGS', '')} "
            f"--xla_force_host_platform_device_count={n}").strip()
    return env


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (possibly fake) devices exist — tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_inference_mesh(data: int = 1, seq: int = 1):
    """Serving mesh for the distributed DiT engine: requests batch
    data-parallel over 'data' replicas, long sequences scatter over 'seq'
    within a replica (repro.distributed, DESIGN.md §distributed)."""
    return jax.make_mesh((data, seq), ("data", "seq"))


def parse_mesh_arg(arg: str):
    """'RxS' (e.g. '1x8') → (data, seq) ints. Raises SystemExit on bad
    input — this parses a CLI flag, matching serve.py's other validators."""
    try:
        data, seq = (int(p) for p in arg.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh expects 'DATAxSEQ' (e.g. 1x8), got {arg!r}")
    if data < 1 or seq < 1:
        raise SystemExit(f"--mesh sizes must be >= 1, got {arg!r}")
    return data, seq
