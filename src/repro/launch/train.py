"""Training launcher.

Examples (CPU, reduced configs):
  python -m repro.launch.train --arch deepseek-7b --smoke --steps 20
  python -m repro.launch.train --arch dit-xl-2 --smoke --steps 50
  python -m repro.launch.train --arch dit-xl-2 --smoke --steps 50 --flexi \
      --recipe shared        # FlexiDiT fine-tune, alternating patch modes

On a real cluster, drop ``--smoke`` and point JAX at the TPU topology; the
mesh/profile/step plumbing is identical to the dry-run's.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data import pipeline as dp
from repro.launch import steps as st
from repro.models import dit as dit_mod
from repro.models import lm
from repro.optim import adamw, ema
from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.runtime.straggler import StragglerDetector


def build_lm_training(cfg, tc, batch, seq):
    params = lm.init_params(cfg, jax.random.PRNGKey(tc.seed))
    opt = adamw.init_opt_state(params)
    step_fn = jax.jit(st.make_train_step(cfg, tc))
    loader = dp.HostShardedLoader(
        dp.make_lm_batch_fn(cfg.vocab_size, seq, batch), seed=tc.seed)
    return params, opt, step_fn, loader


def build_dit_training(cfg, tc, batch, mode=0, trainable=None):
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(tc.seed))
    opt = adamw.init_opt_state(params)
    step_fn = jax.jit(st.make_dit_train_step(cfg, tc, mode=mode,
                                             trainable=trainable))
    loader = dp.HostShardedLoader(
        dp.make_dit_batch_fn(cfg.dit.latent_shape, cfg.dit.num_classes,
                             batch), seed=tc.seed)
    return params, opt, step_fn, loader


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-xl-2")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--flexi", action="store_true",
                    help="FlexiDiT fine-tune: alternate patch modes")
    ap.add_argument("--recipe", default="shared", choices=["shared", "lora"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    tc = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                     total_steps=args.steps)
    ckpt = Checkpointer(Path(args.ckpt_dir) / cfg.name.replace("/", "_"))
    hb = HeartbeatMonitor(n_workers=1, timeout_s=600)
    sd = StragglerDetector(n_workers=1)

    if cfg.family == "dit":
        if args.flexi:
            from repro.core import flexify, trainable_mask
            base_params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
            params, cfg = flexify(base_params, cfg, [(1, 4, 4)],
                                  lora_rank=8 if args.recipe == "lora" else 0)
            mask = (trainable_mask(params, args.recipe)
                    if args.recipe == "lora" else None)
            opt = adamw.init_opt_state(params)
            # two step fns — the paper trains both patch sizes
            step_fns = [jax.jit(st.make_dit_train_step(cfg, tc, mode=m,
                                                       trainable=mask))
                        for m in (0, 1)]
            loader = dp.HostShardedLoader(
                dp.make_dit_batch_fn(cfg.dit.latent_shape,
                                     cfg.dit.num_classes, args.batch))
        else:
            params, opt, fn, loader = build_dit_training(cfg, tc, args.batch)
            step_fns = [fn]
    else:
        params, opt, fn, loader = build_lm_training(cfg, tc, args.batch,
                                                    args.seq)
        step_fns = [fn]

    ema_state = ema.init_ema(params)
    key = jax.random.PRNGKey(42)
    t_start = time.time()
    for step in range(args.steps):
        t0 = time.time()
        batch = next(loader)
        batch = {k: jnp.asarray(v) for k, v in batch.items()
                 if k in ("tokens", "targets", "x0", "cond")}
        fn = step_fns[step % len(step_fns)]
        if cfg.family == "dit":
            params, opt, metrics = fn(params, opt, batch,
                                      jax.random.fold_in(key, step))
        else:
            params, opt, metrics = fn(params, opt, batch)
        ema_state = ema.ema_update(ema_state, params, tc.ema_rate)
        hb.heartbeat(0)
        sd.record(0, (time.time() - t0) * 1e3)
        if step % 10 == 0 or step == args.steps - 1:
            loss = float(metrics.get("loss", metrics.get("distill_loss", 0.0)))  # repro: ignore[hot-host-sync] — logging every 10 steps, intentional sync point
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({(time.time()-t0)*1e3:.0f} ms)", flush=True)
        if step and step % args.ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt})
    ckpt.save(args.steps, {"params": params, "opt": opt})
    ckpt.wait()
    loader.close()
    print(f"done in {time.time()-t_start:.1f}s; "
          f"checkpoints at {ckpt.root}; straggler report: "
          f"{sd.report(args.steps)}")


if __name__ == "__main__":
    main()
