import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first initialization).

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh, print memory/cost analysis, extract roofline terms.

Usage:
  python -m repro.launch.dryrun --arch mamba2-130m --shape train_4k
  python -m repro.launch.dryrun --sweep                 # all cells, 16x16
  python -m repro.launch.dryrun --sweep --multi-pod     # all cells, 2x16x16

Single-cell runs write JSON to results/dryrun/<mesh>/<arch>__<shape>.json.
The sweep shells out one subprocess per cell (compile-memory isolation).
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ASSIGNED_ARCHS, DIT_ARCHS, LM_SHAPES, get_config,
                           cell_is_skipped, get_shape)
from repro.configs.base import TrainConfig
from repro.launch import roofline as rl
from repro.launch import specs as sp
from repro.launch import steps as st
from repro.launch.mesh import make_production_mesh
from repro.models import dit as dit_mod

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def input_specs(arch: str, shape_name: str, mesh) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    if cfg.family == "dit":
        return sp.dit_inputs(cfg, shape_name, mesh)
    shape = get_shape(shape_name)
    if shape.kind == "train":
        return sp.train_inputs(cfg, shape, mesh)
    if shape.kind == "prefill":
        return sp.prefill_inputs(cfg, shape, mesh)
    return sp.decode_inputs(cfg, shape, mesh)


def build_cell(arch: str, shape_name: str, mesh, profile: str = "auto",
               cfg=None, force_single_microbatch: bool = False,
               n_microbatches=None):
    """Returns (jitted_fn, args tuple of ShapeDtypeStructs)."""
    cfg = cfg if cfg is not None else get_config(arch)
    if "_sp" in profile and not cfg.sequence_parallel:
        cfg = dataclasses.replace(cfg, sequence_parallel=True)
    if "_kvq" in profile and cfg.kv_cache_dtype != "int8":
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params_abs, _ = sp.abstract_params(cfg, mesh, profile)

    if cfg.family == "dit":
        inputs = sp.dit_inputs(cfg, shape_name, mesh)
        if shape_name == "train_base":
            tc = TrainConfig()
            fn = st.make_dit_train_step(cfg, tc)
            opt_abs = sp.abstract_opt_state(params_abs, mesh, jnp.float32)
            batch = {k: inputs[k] for k in ("x0", "cond")}
            return (jax.jit(fn, donate_argnums=(0, 1)),
                    (params_abs, opt_abs, batch, inputs["key"]))
        mode = 0 if shape_name == "serve_powerful" else \
            len(cfg.dit.flex_patch_sizes)
        mode_uncond = len(cfg.dit.flex_patch_sizes) if shape_name == "serve_powerful" else mode
        fn = st.make_dit_serve_step(cfg, mode_cond=mode, mode_uncond=mode_uncond)
        return (jax.jit(fn),
                (params_abs, inputs["x_t"], inputs["t"], inputs["cond"],
                 inputs["null_cond"]))

    shape = get_shape(shape_name)
    if shape.kind == "train":
        big = cfg.num_params() > 5e10
        tc = TrainConfig(opt_dtype="bfloat16" if big else "float32")
        n_mb = (n_microbatches if n_microbatches is not None else
                1 if force_single_microbatch else
                sp.choose_microbatches(cfg, shape, mesh))
        fn = st.make_train_step(cfg, tc, n_microbatches=n_mb)
        opt_abs = sp.abstract_opt_state(
            params_abs, mesh,
            jnp.bfloat16 if big else jnp.float32)
        batch = sp.train_inputs(cfg, shape, mesh)
        return (jax.jit(fn, donate_argnums=(0, 1)),
                (params_abs, opt_abs, batch))
    if shape.kind == "prefill":
        fn = st.make_prefill_step(cfg)
        inputs = sp.prefill_inputs(cfg, shape, mesh)
        return jax.jit(fn), (params_abs, inputs)
    fn = st.make_decode_step(cfg)
    inputs = sp.decode_inputs(cfg, shape, mesh)
    return (jax.jit(fn, donate_argnums=(1,)),
            (params_abs, inputs["cache"], inputs["token"], inputs["pos"]))


def _tokens_for_cell(cfg, shape_name: str) -> float:
    if cfg.family == "dit":
        B = sp.DIT_SHAPES[cfg.name][shape_name]
        n_tok = dit_mod.tokens_for_mode(
            cfg, 0 if "powerful" in shape_name or "train" in shape_name
            else len(cfg.dit.flex_patch_sizes))
        return B * n_tok
    shape = get_shape(shape_name)
    if shape.kind == "decode":
        return shape.global_batch          # one new token per sequence
    return shape.global_batch * shape.seq_len


import dataclasses


def _unit_cfg(cfg, n_units: int):
    """Reduced-depth, fully-unrolled variant for the cost calibration
    (XLA cost_analysis counts while-loop bodies once, so scanned costs are
    undercounted by ~L×; we compile unrolled 1- and 2-unit variants and
    extrapolate linearly to the real depth)."""
    kw = dict(unroll=True, remat="none")
    if cfg.family == "vlm":
        kw["num_layers"] = n_units * (cfg.cross_attn_every or 5)
    elif cfg.family == "audio":
        kw["num_layers"] = n_units
        kw["encoder_layers"] = n_units
    else:
        kw["num_layers"] = n_units
    return dataclasses.replace(cfg, **kw)


def _real_units(cfg) -> int:
    if cfg.family == "vlm":
        return cfg.num_layers // (cfg.cross_attn_every or 5)
    return cfg.num_layers


def _cost_of_variant(arch, shape_name, mesh, profile, cfg_variant,
                     n_microbatches=None):
    # REAL model's microbatch count (the reduced-depth variant would compute
    # n_mb=1); the accumulation loop is unrolled under cfg.unroll so
    # per-microbatch collectives are counted honestly
    jitted, args = build_cell(arch, shape_name, mesh, profile,
                              cfg=cfg_variant,
                              n_microbatches=n_microbatches)
    compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis()
    coll = rl.parse_collectives(compiled.as_text())
    return cost, coll


def _extrapolate(c1, c2, units: int):
    """c(u) = fixed + u·per_unit → value at ``units``."""
    out = {}
    for k in set(c1) | set(c2):
        v1 = float(c1.get(k, 0.0) or 0.0)
        v2 = float(c2.get(k, 0.0) or 0.0)
        per = v2 - v1
        out[k] = max(v1 + (units - 1) * per, 0.0)
    return out


def _extrapolate_coll(coll1, coll2, units: int):
    out = {}
    for kind in coll1:
        out[kind] = _extrapolate(coll1[kind], coll2[kind], units)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             profile: str = "auto", out_path=None) -> dict:
    cfg = get_config(arch)
    from repro.runtime.sharding import resolve_profile
    profile = resolve_profile(cfg, profile)
    skip = cell_is_skipped(arch, shape_name) if cfg.family != "dit" else None
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "profile": profile, "status": "skipped", "skip_reason": skip}
    if skip:
        if out_path:
            out_path.parent.mkdir(parents=True, exist_ok=True)
            out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    with jax.set_mesh(mesh):
        # 1) REAL config (scan-over-layers): the memory-fit proof.
        jitted, args = build_cell(arch, shape_name, mesh, profile)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        print(mem)                                # proves it fits
        cost_scanned = compiled.cost_analysis()
        print({k: cost_scanned.get(k) for k in ("flops", "bytes accessed")})
        del compiled, lowered

        # 2) Unrolled 1-unit / 2-unit variants → per-layer cost calibration.
        units = _real_units(cfg)
        n_mb = None
        if (cfg.family != "dit" and get_shape(shape_name).kind == "train"):
            cfg_mb = (dataclasses.replace(cfg, sequence_parallel=True)
                      if profile.endswith("_sp") else cfg)
            n_mb = sp.choose_microbatches(cfg_mb, get_shape(shape_name), mesh)
        c1, coll1 = _cost_of_variant(arch, shape_name, mesh, profile,
                                     _unit_cfg(cfg, 1), n_mb)
        c2, coll2 = _cost_of_variant(arch, shape_name, mesh, profile,
                                     _unit_cfg(cfg, 2), n_mb)
    cost = _extrapolate(
        {k: c1.get(k) for k in ("flops", "bytes accessed", "transcendentals")},
        {k: c2.get(k) for k in ("flops", "bytes accessed", "transcendentals")},
        units)
    coll = _extrapolate_coll(coll1, coll2, units)
    shape_kind = ("train" if ("train" in shape_name) else
                  get_shape(shape_name).kind if cfg.family != "dit" else "serve")
    mf = rl.model_flops(cfg, "train" if shape_kind == "train" else "serve",
                        _tokens_for_cell(cfg, shape_name))
    terms = rl.roofline_terms(cost, coll, n_dev, mf)

    mem_rec = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        mem_rec[attr] = getattr(mem, attr, None)
    args_bytes = sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree.leaves(args)) / n_dev

    rec.update({
        "status": "ok", "n_devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_rec,
        "sharded_args_bytes_per_device": args_bytes,
        "cost_analysis": {k: float(v) for k, v in cost.items()},
        "cost_analysis_scanned_raw": {
            k: float(cost_scanned.get(k) or 0.0)
            for k in ("flops", "bytes accessed")},
        "collectives": coll,
        "roofline": terms,
        "params": cfg.num_params(),
        "active_params": cfg.active_params(),
    })
    if out_path:
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=1))
    return rec


def all_cells():
    cells = []
    for arch in ASSIGNED_ARCHS:
        for shape in LM_SHAPES:
            cells.append((arch, shape.name))
    for arch in DIT_ARCHS:
        for shape in ("train_base", "serve_powerful", "serve_weak"):
            cells.append((arch, shape))
    return cells


def sweep(multi_pod: bool, profile: str = "auto", only_missing: bool = True):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    outdir = RESULTS / mesh_name
    outdir.mkdir(parents=True, exist_ok=True)
    for arch, shape in all_cells():
        out = outdir / f"{arch}__{shape}.json"
        if only_missing and out.exists():
            print(f"[skip-existing] {arch} {shape}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--profile", profile,
               "--out", str(out)]
        if multi_pod:
            cmd.append("--multi-pod")
        print(f"[run] {arch} {shape} ({mesh_name})", flush=True)
        t0 = time.time()
        r = subprocess.run(cmd, capture_output=True, text=True)
        dt = time.time() - t0
        if r.returncode != 0:
            out.write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "error",
                "error": r.stderr[-4000:] if r.stderr else r.stdout[-2000:],
            }, indent=1))
            print(f"[FAIL {dt:.0f}s] {arch} {shape}\n{r.stderr[-1500:]}",
                  flush=True)
        else:
            print(f"[ok {dt:.0f}s] {arch} {shape}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--profile", default="auto")
    ap.add_argument("--out", default=None)
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.sweep:
        sweep(args.multi_pod, args.profile, only_missing=not args.force)
        return
    out = Path(args.out) if args.out else None
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.profile, out)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("collectives",)}, indent=1, default=str))


if __name__ == "__main__":
    main()
