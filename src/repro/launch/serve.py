"""Batched serving drivers.

LM path: prefill + greedy decode over fixed batch slots
(continuous-batching-lite: finished slots are refilled from the request
queue between decode steps).

DiT path: FlexiPipeline-backed image serving over fixed batch slots. Each
request carries a class label and a relative-compute budget; requests are
bucketed onto a plan menu (one ``SamplingPlan`` per ``--budget-levels``
entry), batches are padded to exactly ``--batch-slots`` so every batch of
a bucket reuses one compiled phase runner, and budget switches between
batches never recompile (DESIGN.md §pipeline). With ``--mesh DATAxSEQ``
the pipeline runs on a device mesh: batches go data-parallel across the
replica axis while each request's token sequence scatters over the 'seq'
axis through the distributed engine (DESIGN.md §distributed).

  python -m repro.launch.serve --arch deepseek-7b --smoke --requests 8
  python -m repro.launch.serve --arch dit-xl-2 --budget 0.6 --smoke
  python -m repro.launch.serve --arch dit-xl-2 --mesh 1x8 --budget 0.6 --smoke
"""
from __future__ import annotations

import argparse
import os
import time
from collections import defaultdict
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import steps as st
from repro.models import lm


def parse_budget_levels(arg: Optional[str], base: float) -> List[float]:
    """``--budget-levels`` 'a,b,c' → sorted, deduped, validated floats in
    (0, 1]; default menu derived from ``--budget`` when unset. Validation
    runs on the ROUNDED values (and on the default menu too) so nothing
    outside (0, 1] ever reaches ``SamplingPlan``."""
    if not arg:
        raw = [base, (base + 1.0) / 2, 1.0]
    else:
        raw = []
        for part in arg.split(","):
            part = part.strip()
            if not part:
                continue
            try:
                raw.append(float(part))
            except ValueError:
                raise SystemExit(f"--budget-levels: {part!r} is not a number")
        if not raw:
            raise SystemExit("--budget-levels: no levels given")
    levels = set()
    for b in raw:
        b = round(b, 2)
        if not 0.0 < b <= 1.0:
            raise SystemExit(f"--budget-levels/--budget: level {b} "
                             f"outside (0, 1]")
        levels.add(b)
    return sorted(levels)


def serve_dit(cfg, args) -> None:
    """Serve DiT sampling requests from a queue over fixed batch slots."""
    from repro.diffusion import schedule as sch
    from repro.launch.mesh import make_inference_mesh, parse_mesh_arg
    from repro.models import dit as dit_mod
    from repro.pipeline import FlexiPipeline, ParallelSpec, SamplingPlan

    mesh = None
    parallel = None
    if getattr(args, "mesh", None):
        d_sz, s_sz = parse_mesh_arg(args.mesh)
        mesh = make_inference_mesh(d_sz, s_sz)
        if s_sz > 1:
            parallel = ParallelSpec()
        print(f"[mesh] data={d_sz} seq={s_sz} over "
              f"{len(mesh.devices.flat)} devices")

    key = jax.random.PRNGKey(0)
    params = dit_mod.init_dit(cfg, key)          # smoke: untrained weights
    pipe = FlexiPipeline(params, cfg, sch.linear_schedule(args.train_T),
                         mesh=mesh)
    T, B = args.T, args.batch_slots

    # Plan menu: requests are quantized onto a few budget levels so each
    # level compiles exactly once and batches can share slots.
    levels = parse_budget_levels(getattr(args, "budget_levels", None),
                                 args.budget)
    plans: Dict[float, SamplingPlan] = {}
    for b in levels:
        plan = SamplingPlan(T=T, budget=float(b), solver=args.solver,
                            guidance_scale=args.cfg_scale, parallel=parallel)
        plan.validate(cfg)
        plans[b] = plan
        fs = plan.resolve_schedule(cfg)
        print(f"[plan] budget<={b:.2f}: T_weak={fs.phases[0][1]}/{T} "
              f"relative_compute={plan.relative_compute(cfg):.3f}")
        if parallel is not None:
            from repro.distributed import plan_partition
            part = plan_partition(cfg, fs, s_sz, parallel)
            per_phase = " ".join(
                f"m{p.mode}:{p.tokens}+{p.pad}pad/{p.sp}" for p, nn in
                part.phases if nn)
            coll = part.collective_bytes(
                cfg, cfg_scale_active=args.cfg_scale != 0)
            print(f"[shard]   {per_phase} impl="
                  f"{part.phases[0][0].impl} "
                  f"collective={coll / 1e6:.1f}MB/sample "
                  f"eff={part.parallel_efficiency(cfg):.3f}")

    rng = np.random.default_rng(0)
    queue: Dict[float, List[int]] = defaultdict(list)   # budget → labels
    for i in range(args.requests):
        queue[levels[i % len(levels)]].append(
            int(rng.integers(0, cfg.dit.num_classes)))

    done = 0
    batches = 0
    total_flops = 0.0
    t0 = time.time()
    while any(queue.values()):
        # fill the slots from the fullest bucket (continuous-batching-lite)
        b = max(queue, key=lambda k: len(queue[k]))
        labels = [queue[b].pop(0) for _ in range(min(B, len(queue[b])))]
        n_real = len(labels)
        # pad to exactly B slots so every batch hits the same executable
        labels += [labels[-1]] * (B - n_real)
        res = pipe.sample(plans[b], B, jax.random.fold_in(key, 100 + batches),
                          cond=jnp.asarray(labels, jnp.int32))
        jax.block_until_ready(res.x0)
        done += n_real
        batches += 1
        total_flops += res.flops * n_real / B
        print(f"[batch {batches}] budget={b:.2f} served={n_real} "
              f"(pad={B - n_real}) rel_compute={res.relative_compute:.3f} "
              f"x0_std={float(jnp.std(res.x0[:n_real])):.3f}", flush=True)
    dt = time.time() - t0
    stats = pipe.cache_stats()
    print(f"served {done} requests in {batches} batches, {dt:.1f}s "
          f"({done / max(dt, 1e-9):.2f} img/s), "
          f"{total_flops / 1e9:.2f} GFLOPs total")
    print(f"[cache] runners={stats['runners']} compiled={stats['compiled']} "
          f"hits={stats['hits']} misses={stats['misses']}")
    assert stats["compiled"] <= len(levels), \
        "budget switches must not recompile beyond one runner per plan"


def serve_lm(cfg, args) -> None:
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B = args.batch_slots
    S_max = args.prompt_len + args.max_new

    prefill = jax.jit(st.make_prefill_step(cfg))
    decode = jax.jit(st.make_decode_step(cfg))

    rng = np.random.default_rng(0)
    pending: List[np.ndarray] = [
        rng.integers(0, cfg.vocab_size, size=(args.prompt_len,),
                     dtype=np.int32)
        for _ in range(args.requests)]
    done = 0
    t0 = time.time()
    tokens_out = 0
    while pending or done < args.requests:
        batch_prompts = [pending.pop(0) for _ in range(min(B, len(pending)))]
        if not batch_prompts:
            break
        prompts = jnp.asarray(np.stack(batch_prompts))
        inputs = {"tokens": prompts}
        if cfg.family == "vlm":
            inputs["vision"] = jnp.zeros((len(batch_prompts),
                                          cfg.vision_tokens, cfg.d_model))
        if cfg.family == "audio":
            inputs["frames"] = jnp.zeros((len(batch_prompts),
                                          cfg.audio_frames, cfg.d_model))
        logits, cache = prefill(params, inputs)
        # pad cache along seq to S_max so decode can write new positions
        def pad_seq(x):
            if x.ndim >= 4 and x.shape[-3] == args.prompt_len:
                pad = [(0, 0)] * x.ndim
                pad[-3] = (0, args.max_new)
                return jnp.pad(x, pad)
            return x
        cache = jax.tree.map(pad_seq, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs = [tok]
        for i in range(args.max_new - 1):
            pos = jnp.full((len(batch_prompts),), args.prompt_len + i,
                           jnp.int32)
            logits, cache = decode(params, cache, tok, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            outs.append(tok)
            tokens_out += len(batch_prompts)
        done += len(batch_prompts)
        gen = jnp.concatenate(outs, axis=1)
        print(f"[batch done] {len(batch_prompts)} reqs, "
              f"first gen: {np.asarray(gen[0])[:8].tolist()}", flush=True)
    dt = time.time() - t0
    print(f"served {done} requests, {tokens_out} tokens in {dt:.1f}s "
          f"({tokens_out/max(dt,1e-9):.1f} tok/s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    # LM path
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    # DiT path
    ap.add_argument("--budget", type=float, default=0.6,
                    help="base relative-compute budget for DiT requests")
    ap.add_argument("--budget-levels", default=None,
                    help="comma-separated relative-compute menu, e.g. "
                         "'0.4,0.6,1.0' (default: derived from --budget)")
    ap.add_argument("--mesh", default=None,
                    help="DATAxSEQ device mesh for the DiT path, e.g. 1x8: "
                         "data-parallel replicas x sequence-parallel shards")
    ap.add_argument("--T", type=int, default=20,
                    help="DiT denoising steps per request")
    ap.add_argument("--train-T", type=int, default=1000,
                    help="diffusion schedule length the DiT was trained at")
    ap.add_argument("--solver", default="ddim",
                    choices=["ddim", "ddpm", "dpm2"])
    ap.add_argument("--cfg-scale", type=float, default=1.5)
    args = ap.parse_args()

    if args.mesh:
        # CPU smoke runs: make sure enough host devices exist BEFORE the
        # jax backend initializes.
        from repro.launch.mesh import ensure_host_devices, parse_mesh_arg
        ensure_host_devices(int(np.prod(parse_mesh_arg(args.mesh))))

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.family == "dit":
        serve_dit(cfg, args)
    else:
        serve_lm(cfg, args)


if __name__ == "__main__":
    main()
