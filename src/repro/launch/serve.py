"""Batched LM serving driver: prefill + greedy decode over fixed batch
slots (continuous-batching-lite: finished slots are refilled from the
request queue between decode steps).

  python -m repro.launch.serve --arch deepseek-7b --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import steps as st
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.family == "dit":
        raise SystemExit("use examples/flexidit_sample.py for DiT serving")

    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B = args.batch_slots
    S_max = args.prompt_len + args.max_new

    prefill = jax.jit(st.make_prefill_step(cfg))
    decode = jax.jit(st.make_decode_step(cfg))

    rng = np.random.default_rng(0)
    pending: List[np.ndarray] = [
        rng.integers(0, cfg.vocab_size, size=(args.prompt_len,),
                     dtype=np.int32)
        for _ in range(args.requests)]
    done = 0
    t0 = time.time()
    tokens_out = 0
    while pending or done < args.requests:
        batch_prompts = [pending.pop(0) for _ in range(min(B, len(pending)))]
        if not batch_prompts:
            break
        prompts = jnp.asarray(np.stack(batch_prompts))
        inputs = {"tokens": prompts}
        if cfg.family == "vlm":
            inputs["vision"] = jnp.zeros((len(batch_prompts),
                                          cfg.vision_tokens, cfg.d_model))
        if cfg.family == "audio":
            inputs["frames"] = jnp.zeros((len(batch_prompts),
                                          cfg.audio_frames, cfg.d_model))
        logits, cache = prefill(params, inputs)
        # pad cache along seq to S_max so decode can write new positions
        def pad_seq(x):
            if x.ndim >= 4 and x.shape[-3] == args.prompt_len:
                pad = [(0, 0)] * x.ndim
                pad[-3] = (0, args.max_new)
                return jnp.pad(x, pad)
            return x
        cache = jax.tree.map(pad_seq, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs = [tok]
        for i in range(args.max_new - 1):
            pos = jnp.full((len(batch_prompts),), args.prompt_len + i,
                           jnp.int32)
            logits, cache = decode(params, cache, tok, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            outs.append(tok)
            tokens_out += len(batch_prompts)
        done += len(batch_prompts)
        gen = jnp.concatenate(outs, axis=1)
        print(f"[batch done] {len(batch_prompts)} reqs, "
              f"first gen: {np.asarray(gen[0])[:8].tolist()}", flush=True)
    dt = time.time() - t0
    print(f"served {done} requests, {tokens_out} tokens in {dt:.1f}s "
          f"({tokens_out/max(dt,1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
