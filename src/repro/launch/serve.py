"""Batched serving drivers.

LM path: prefill + greedy decode over fixed batch slots
(continuous-batching-lite: finished slots are refilled from the request
queue between decode steps).

DiT path: the continuous-batching serving engine (``repro.serving``,
DESIGN.md §serving). Requests carry a class label, a relative-compute
budget quantized onto the ``--budget-levels`` plan menu, and an optional
deadline; the engine keeps many requests in flight at different denoise
steps and packs each iteration token-wise (weak-phase requests
contribute fewer tokens) into compile-once bucket layouts under
``--max-tokens-per-step``. ``--policy`` picks admission/step ordering:
``fifo``, ``edf`` (earliest deadline first), or ``degrade`` (SLA-aware:
queued requests are demoted to the highest budget level the measured
arrival rate sustains).

``--replicas N`` serves through the fleet router (``repro.fleet``,
DESIGN.md §fleet): N in-process replica engines behind one front door,
placement picked by ``--router`` (cheapest priced backlog, cache
affinity, or round-robin), with heartbeat fault tolerance and elastic
drain/join. ``--mesh DATAxSEQ --replicas N`` composes the two layers:
N == DATA sequence-parallel replicas, each a fixed-slot engine over its
own SEQ-wide device mesh, routed by the same fleet policies. A bare
``--mesh`` without ``--replicas`` keeps the legacy single-driver
fixed-slot path (DESIGN.md §distributed).

Telemetry (DESIGN.md §telemetry): ``--trace out.json`` records the
request lifecycle (admit → plan → pack → dispatch → materialize →
finish, plus compile events) and the on-device taps, dumping a
Chrome-trace JSON loadable in https://ui.perfetto.dev;
``--metrics-interval N`` emits one structured ``[metrics]`` line every
N engine steps. Either flag routes dispatches through the tapped step
family — bit-identical latents, zero extra compiles.

  python -m repro.launch.serve --arch deepseek-7b --smoke --requests 8
  python -m repro.launch.serve --arch dit-xl-2 --budget 0.6 --smoke
  python -m repro.launch.serve --arch dit-xl-2 --smoke --policy degrade
  python -m repro.launch.serve --arch dit-xl-2 --mesh 1x8 --budget 0.6 --smoke
  python -m repro.launch.serve --arch dit-xl-2 --smoke --replicas 4 \
      --router affinity
  python -m repro.launch.serve --arch dit-xl-2 --smoke --mesh 2x4 --replicas 2
  python -m repro.launch.serve --arch dit-xl-2 --smoke --attn-backend dense \
      --cache-policy interval --trace trace.json --metrics-interval 25
"""
from __future__ import annotations

import argparse
import os
import time
from collections import defaultdict
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import steps as st
from repro.models import lm
from repro.runtime.padding import pad_kv_cache


def parse_budget_levels(arg: Optional[str], base: float) -> List[float]:
    """``--budget-levels`` 'a,b,c' → sorted, deduped, validated floats in
    (0, 1]; default menu derived from ``--budget`` when unset. Validation
    runs on the ROUNDED values (and on the default menu too) so nothing
    outside (0, 1] ever reaches ``SamplingPlan``."""
    if not arg:
        raw = [base, (base + 1.0) / 2, 1.0]
    else:
        raw = []
        for part in arg.split(","):
            part = part.strip()
            if not part:
                continue
            try:
                raw.append(float(part))
            except ValueError:
                raise SystemExit(f"--budget-levels: {part!r} is not a number")
        if not raw:
            raise SystemExit("--budget-levels: no levels given")
    levels = set()
    for b in raw:
        b = round(b, 2)
        if not 0.0 < b <= 1.0:
            raise SystemExit(f"--budget-levels/--budget: level {b} "
                             f"outside (0, 1]")
        levels.add(b)
    return sorted(levels)


def build_plan_menu(cfg, args, parallel=None) -> Dict[float, "object"]:
    """``--budget-levels`` → validated ``{level: SamplingPlan}``, printing
    one ``[plan]`` line per level."""
    from repro.pipeline import SamplingPlan

    levels = parse_budget_levels(getattr(args, "budget_levels", None),
                                 args.budget)
    plans: Dict[float, SamplingPlan] = {}
    for b in levels:
        plan = SamplingPlan(T=args.T, budget=float(b), solver=args.solver,
                            guidance_scale=args.cfg_scale, parallel=parallel,
                            attn_backend=getattr(args, "attn_backend",
                                                 "auto") or "auto")
        plan.validate(cfg)
        plans[b] = plan
        fs = plan.resolve_schedule(cfg)
        print(f"[plan] budget<={b:.2f}: T_weak={fs.phases[0][1]}/{args.T} "
              f"relative_compute={plan.relative_compute(cfg):.3f}")
    return plans


def serve_dit(cfg, args) -> None:
    """Serve DiT sampling requests: continuous-batching engine by default,
    the fleet router under ``--replicas``, the legacy fixed-slot mesh
    driver under a bare ``--mesh``."""
    from repro.diffusion import schedule as sch
    from repro.launch.mesh import make_inference_mesh, parse_mesh_arg
    from repro.models import dit as dit_mod
    from repro.pipeline import FlexiPipeline, ParallelSpec

    if getattr(args, "replicas", 1) > 1:
        _serve_dit_fleet(cfg, args)
        return

    mesh = None
    parallel = None
    s_sz = 1
    if getattr(args, "mesh", None):
        d_sz, s_sz = parse_mesh_arg(args.mesh)
        mesh = make_inference_mesh(d_sz, s_sz)
        if s_sz > 1:
            parallel = ParallelSpec()
        print(f"[mesh] data={d_sz} seq={s_sz} over "
              f"{len(mesh.devices.flat)} devices")

    key = jax.random.PRNGKey(0)
    params = dit_mod.init_dit(cfg, key)          # smoke: untrained weights
    pipe = FlexiPipeline(params, cfg, sch.linear_schedule(args.train_T),
                         mesh=mesh)
    plans = build_plan_menu(cfg, args, parallel)
    if mesh is not None:
        _serve_dit_fixed_slots(cfg, args, pipe, plans, s_sz, parallel, key)
    else:
        _serve_dit_engine(cfg, args, pipe, plans)


def _serve_dit_engine(cfg, args, pipe, plans) -> None:
    """The continuous-batching path (DESIGN.md §serving)."""
    from repro.serving import CacheSpec, ServingEngine
    from repro.telemetry import Telemetry
    from repro.telemetry import export as tel_export

    policy = getattr(args, "policy", None) or "fifo"
    max_tokens = getattr(args, "max_tokens_per_step", None)
    cache = None
    cache_policy = getattr(args, "cache_policy", None) or "off"
    if cache_policy != "off":
        cache = CacheSpec(policy=cache_policy,
                          interval=getattr(args, "cache_interval", 2),
                          threshold=getattr(args, "cache_threshold", 0.05))
        print(f"[cache] activation cache on: policy={cache.policy} "
              f"interval={cache.interval} threshold={cache.threshold} "
              f"split={cache.resolve_split(cfg.num_layers)}/"
              f"{cfg.num_layers} blocks")
    trace_path = getattr(args, "trace", None)
    metrics_interval = getattr(args, "metrics_interval", 0) or 0
    profile = bool(getattr(args, "profile", False))
    pm_dir = getattr(args, "postmortem_dir", None)
    slo_p99 = getattr(args, "slo_p99", None)
    telemetry = None
    if trace_path or metrics_interval or profile or pm_dir or slo_p99:
        # tracing implies taps: the tapped step family is bit-identical
        # and compile-parallel to the untapped one (DESIGN.md §telemetry)
        watchdog = None
        if pm_dir or slo_p99:
            from repro.telemetry.watchdog import Watchdog, WatchdogConfig
            watchdog = Watchdog(WatchdogConfig(p99_slo_s=slo_p99))
        telemetry = Telemetry(taps=True, profile=profile,
                              watchdog=watchdog, postmortem_dir=pm_dir)
        print(f"[telemetry] spans+taps on"
              + (", compiled-cost profiling on" if profile else "")
              + (f", post-mortems -> {pm_dir}" if pm_dir else "")
              + (f", trace -> {trace_path}" if trace_path else ""))
    engine = ServingEngine(pipe, plans, policy=policy,
                           max_tokens_per_step=max_tokens, cache=cache,
                           telemetry=telemetry)
    # warm-set shaping (ROADMAP): compile the small-cohort bucket ladder
    # off the hot path so mid-trace arrivals never meet a coarse layout
    n_pre = engine.precapture_warm_set(max_per_mode=2)
    print(f"[warm-set] precaptured {n_pre} small-cohort executables")
    print(engine.menu.describe())

    levels = sorted(plans)
    rng = np.random.default_rng(0)

    def submit_wave(n: int) -> None:
        now = engine.clock()
        for i in range(n):
            deadline = now + float(rng.uniform(0.5, 5.0))
            engine.submit(cond=int(rng.integers(0, cfg.dit.num_classes)),
                          budget=levels[i % len(levels)], deadline=deadline)

    t0 = time.time()

    def drain():
        """engine.run(), stepwise, emitting the periodic metrics line."""
        out = []
        while not engine.idle:
            out.extend(engine.step())
            if metrics_interval and \
                    engine.metrics.total_steps % metrics_interval == 0:
                print(tel_export.metrics_line(
                    engine.metrics.summary(wall=time.time() - t0),
                    taps=(telemetry.taps.aggregate()
                          if telemetry is not None else None),
                    compile_stats=engine.cache_stats(),
                    spans=(telemetry.recorder.counters()
                           if telemetry is not None else None)))
        return out

    # warmup wave compiles the bucket layouts this workload visits ...
    submit_wave(args.requests)
    results = drain()
    warm = engine.cache_stats()
    # ... after which serving the same workload shape is compile-free
    submit_wave(args.requests)
    results += drain()
    dt = time.time() - t0

    done = len(results)
    stats = engine.cache_stats()
    m = engine.metrics.summary(wall=dt)
    for r in results[:4]:
        print(f"[served] req={r.request.id} budget={r.budget_served:.2f} "
              f"latency={r.record.latency:.2f}s "
              f"x0_std={float(jnp.std(r.x0)):.3f}", flush=True)  # repro: ignore[hot-host-sync] — 4-sample debug print after drain
    print(f"served {done} requests in {int(m['steps'])} engine steps, "
          f"{dt:.1f}s ({done / max(dt, 1e-9):.2f} img/s), "
          f"{m.get('flops', 0.0) / 1e9:.2f} GFLOPs total")
    print(f"[metrics] policy={policy} p50={m.get('p50', 0.0):.2f}s "
          f"p99={m.get('p99', 0.0):.2f}s "
          f"packing_eff={m['packing_efficiency']:.3f} "
          f"deadline_hit={m.get('deadline_hit_rate', 1.0):.2f} "
          f"degraded={int(m['degraded'])}")
    if "attn_block_skip_rate" in m:
        print(f"[attn] backend={engine.attn_backend} "
              f"block_skip_rate={m['attn_block_skip_rate']:.3f} "
              f"(cross-segment score tiles never issued)")
    print(f"[cache] runners={stats['runners']} compiled={stats['compiled']} "
          f"hits={stats['hits']} misses={stats['misses']}")
    if cache is not None:
        cs = engine.metrics.cache_summary()
        print(f"[act-cache] hit_rate={cs['hit_rate']:.3f} "
              f"refreshes={cs['refreshes']} skips={cs['skips']} "
              f"interval_hist={cs['refresh_interval_hist']} "
              f"store_bytes_total={engine.store.bytes_total}")
    if telemetry is not None:
        agg = telemetry.taps.aggregate()
        if "drift" in agg:
            print(f"[taps] drift_mean={agg['drift']['mean']:.4g} "
                  f"drift_max={agg['drift']['max']:.4g} "
                  f"eps_norm_mean={agg['eps_norm']['mean']:.4g} over "
                  f"{agg['request_steps']} request-steps")
        elif "eps_norm" in agg:
            print(f"[taps] eps_norm_mean={agg['eps_norm']['mean']:.4g} "
                  f"over {agg['request_steps']} request-steps")
        print(tel_export.metrics_line(m, taps=agg, compile_stats=stats,
                                      spans=telemetry.recorder.counters(),
                                      tag="metrics-final"))
        if profile:
            # harvest AOT compiled costs for the whole warm set and
            # reconcile: analytic ledger vs XLA vs measured wall
            hv = telemetry.profile.harvest(pipe)
            hstats = engine.cache_stats()
            assert hstats["compiled"] == stats["compiled"], \
                "AOT cost harvest must not touch the jit compile cache"
            print(f"[profile] harvest: {hv}")
            for line in telemetry.profile.report_lines():
                print(line)
            cons = telemetry.attribution.conservation()
            print(f"[attrib] conservation deltas {cons} over "
                  f"{len(telemetry.attribution.finalized)} finalized "
                  f"requests (all must be 0)")
            for r in results[:4]:
                if r.cost is not None:
                    c = r.cost
                    print(f"[attrib] req={c.request_id} "
                          f"flops={c.flops / 1e9:.2f}G "
                          f"bytes={c.bytes / 1e6:.1f}MB "
                          f"wall={c.wall_ms:.1f}ms "
                          f"dispatches={c.dispatches} "
                          f"queue_wait={c.queue_wait_s:.3f}s")
            calib = (engine.controller.calibration
                     if engine.controller is not None else None)
            if calib:
                fams = {m: f"{v:.3e}"
                        for m, v in calib["per_family"].items()}
                print(f"[calib] wall_per_analytic_flop "
                      f"global={calib['global']:.3e} per_family={fams}")
        if telemetry.watchdog is not None and telemetry.watchdog.alerts:
            for a in telemetry.watchdog.alerts:
                print(f"[alert] {a.kind} step={a.step} value={a.value:.4g} "
                      f"limit={a.limit:.4g} {a.detail}")
            if telemetry.watchdog.dumps_written:
                print(f"[postmortem] "
                      f"{len(telemetry.watchdog.dumps_written)} bundle(s) "
                      f"-> {telemetry.watchdog.dumps_written}")
        if trace_path:
            # drift/eps counter tracks: the timeline shows WHEN replay
            # error spiked, aligned with the dispatch spans
            for when, vals in telemetry.taps.counter_series():
                telemetry.recorder.counter("taps", vals, ts=when)
            telemetry.recorder.dump(trace_path)
            print(f"[trace] {telemetry.recorder.events_recorded} events "
                  f"({telemetry.recorder.events_dropped} dropped) -> "
                  f"{trace_path} (open in ui.perfetto.dev)")
    # only the fifo drain replays deterministically (edf priorities move
    # with the wall clock, degradation shifts the level mix); frozen-mode
    # zero-compile serving for those is exercised in bench_serving
    if policy == "fifo":
        assert stats["compiled"] == warm["compiled"], \
            "steady-state serving must not recompile after bucket warmup"


def _serve_dit_fleet(cfg, args) -> None:
    """The fleet path (DESIGN.md §fleet): ``--replicas N`` in-process
    replica engines behind the router. Without ``--mesh`` every replica
    is a packed continuous-batching engine sharing one pipeline; with
    ``--mesh DATAxSEQ`` (DATA == N) each replica is a fixed-slot engine
    over its own contiguous SEQ-wide device slice, so sequence-parallel
    sharding composes with fleet routing."""
    from repro.diffusion import schedule as sch
    from repro.fleet import Fleet, partition_devices
    from repro.launch.mesh import parse_mesh_arg
    from repro.models import dit as dit_mod
    from repro.pipeline import FlexiPipeline, ParallelSpec

    n = args.replicas
    key = jax.random.PRNGKey(0)
    params = dit_mod.init_dit(cfg, key)          # smoke: untrained weights
    sched = sch.linear_schedule(args.train_T)
    s_sz = 1
    parallel = None
    pipes = None
    engine_kind = "packed"
    engine_kwargs = None
    if getattr(args, "mesh", None):
        d_sz, s_sz = parse_mesh_arg(args.mesh)
        if d_sz != n:
            raise SystemExit(f"--mesh {args.mesh}: DATA={d_sz} must equal "
                             f"--replicas {n} on the fleet path (one "
                             f"replica per data-parallel slice)")
        if s_sz > 1:
            # packed engines are single-replica; seq-parallel replicas run
            # fixed-slot engines over per-replica meshes
            parallel = ParallelSpec()
            engine_kind = "fixed"
        devs = jax.devices()
        slices = partition_devices(range(n * s_sz), n, s_sz)
        pipes = []
        for sl in slices:
            mesh = jax.make_mesh((1, s_sz), ("data", "seq"),
                                 devices=[devs[i] for i in sl])
            pipes.append(FlexiPipeline(params, cfg, sched, mesh=mesh))
        print(f"[mesh] {n} replica(s) x seq={s_sz}: slices "
              f"{[list(s) for s in slices]}")
    plans = build_plan_menu(cfg, args, parallel)
    if engine_kind == "packed":
        engine_kwargs = {"policy": getattr(args, "policy", None) or "fifo",
                         "max_tokens_per_step":
                             getattr(args, "max_tokens_per_step", None)}
    pipe = pipes[0] if pipes else FlexiPipeline(params, cfg, sched)
    fleet = Fleet(pipe, plans, n, router=args.router,
                  pipes=pipes, engine_kind=engine_kind,
                  seq_parallel=s_sz, batch_size=args.batch_slots,
                  engine_kwargs=engine_kwargs)
    if engine_kind == "packed":
        # warm the small-cohort ladder off the serving path: replicas
        # share one pipeline, so one background walk warms them all
        from repro.fleet import BackgroundCompiler
        fleet.warmers[0] = BackgroundCompiler(
            fleet.replicas[0].engine, name="serve-warm").start()

    levels = sorted(plans)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        deadline = fleet.now + float(rng.uniform(0.5, 5.0))
        fleet.submit(cond=int(rng.integers(0, cfg.dit.num_classes)),
                     budget=levels[i % len(levels)], deadline=deadline)
    results = fleet.run()
    if engine_kind == "packed":
        fleet.wait_warm(timeout=600.0)
    dt = time.time() - t0
    s = fleet.summary()
    for r in results[:4]:
        print(f"[served] req={r.rid} replica={r.replica} "
              f"budget={r.budget_served:.2f} latency={r.latency:.2f}s "
              f"x0_std={float(jnp.std(r.x0)):.3f}", flush=True)  # repro: ignore[hot-host-sync] — 4-sample debug print after drain
    print(f"[fleet] served {int(s['served'])} requests over "
          f"{s['replicas']} replicas in {dt:.1f}s "
          f"({len(results) / max(dt, 1e-9):.2f} img/s) "
          f"router={args.router}")
    print(f"[fleet] affinity_hit_rate={s['affinity_hit_rate']:.3f} "
          f"placements={int(s['router']['placements'])} "
          f"handbacks={int(s['router']['handbacks'])} "
          f"hedges={int(s['router']['hedges'])}")
    c = s["compile"]
    print(f"[cache] pipes={c['pipes']} runners={c['runners']} "
          f"compiled={c['compiled']} hits={c['hits']} "
          f"misses={c['misses']}")


def _serve_dit_fixed_slots(cfg, args, pipe, plans, s_sz, parallel, key
                           ) -> None:
    """Legacy fixed-batch-slot driver, kept for ``--mesh`` runs (the
    packed engine is single-host)."""
    T, B = args.T, args.batch_slots
    levels = sorted(plans)
    if parallel is not None:
        from repro.distributed import plan_partition
        for b in levels:
            fs = plans[b].resolve_schedule(cfg)
            part = plan_partition(cfg, fs, s_sz, parallel)
            per_phase = " ".join(
                f"m{p.mode}:{p.tokens}+{p.pad}pad/{p.sp}" for p, nn in
                part.phases if nn)
            coll = part.collective_bytes(
                cfg, cfg_scale_active=args.cfg_scale != 0)
            print(f"[shard]   {per_phase} impl="
                  f"{part.phases[0][0].impl} "
                  f"collective={coll / 1e6:.1f}MB/sample "
                  f"eff={part.parallel_efficiency(cfg):.3f}")

    rng = np.random.default_rng(0)
    queue: Dict[float, List[int]] = defaultdict(list)   # budget → labels
    for i in range(args.requests):
        queue[levels[i % len(levels)]].append(
            int(rng.integers(0, cfg.dit.num_classes)))

    done = 0
    batches = 0
    total_flops = 0.0
    t0 = time.time()
    while any(queue.values()):
        # fill the slots from the fullest bucket (continuous-batching-lite)
        b = max(queue, key=lambda k: len(queue[k]))
        labels = [queue[b].pop(0) for _ in range(min(B, len(queue[b])))]
        n_real = len(labels)
        # pad to exactly B slots so every batch hits the same executable
        labels += [labels[-1]] * (B - n_real)
        res = pipe.sample(plans[b], B, jax.random.fold_in(key, 100 + batches),
                          cond=jnp.asarray(labels, jnp.int32))
        jax.block_until_ready(res.x0)
        done += n_real
        batches += 1
        total_flops += res.flops * n_real / B
        print(f"[batch {batches}] budget={b:.2f} served={n_real} "
              f"(pad={B - n_real}) rel_compute={res.relative_compute:.3f} "
              f"x0_std={float(jnp.std(res.x0[:n_real])):.3f}", flush=True)  # repro: ignore[hot-host-sync] — per-batch progress log
    dt = time.time() - t0
    stats = pipe.cache_stats()
    print(f"served {done} requests in {batches} batches, {dt:.1f}s "
          f"({done / max(dt, 1e-9):.2f} img/s), "
          f"{total_flops / 1e9:.2f} GFLOPs total")
    print(f"[cache] runners={stats['runners']} compiled={stats['compiled']} "
          f"hits={stats['hits']} misses={stats['misses']}")
    assert stats["compiled"] <= len(levels), \
        "budget switches must not recompile beyond one runner per plan"


def serve_lm(cfg, args) -> None:
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B = args.batch_slots
    S_max = args.prompt_len + args.max_new

    prefill = jax.jit(st.make_prefill_step(cfg))
    decode = jax.jit(st.make_decode_step(cfg))

    rng = np.random.default_rng(0)
    pending: List[np.ndarray] = [
        rng.integers(0, cfg.vocab_size, size=(args.prompt_len,),
                     dtype=np.int32)
        for _ in range(args.requests)]
    done = 0
    t0 = time.time()
    tokens_out = 0
    while pending or done < args.requests:
        batch_prompts = [pending.pop(0) for _ in range(min(B, len(pending)))]
        if not batch_prompts:
            break
        prompts = jnp.asarray(np.stack(batch_prompts))
        inputs = {"tokens": prompts}
        if cfg.family == "vlm":
            inputs["vision"] = jnp.zeros((len(batch_prompts),
                                          cfg.vision_tokens, cfg.d_model))
        if cfg.family == "audio":
            inputs["frames"] = jnp.zeros((len(batch_prompts),
                                          cfg.audio_frames, cfg.d_model))
        logits, cache = prefill(params, inputs)
        # pad cache along seq to S_max so decode can write new positions
        cache = pad_kv_cache(cache, args.prompt_len, args.max_new)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs = [tok]
        for i in range(args.max_new - 1):
            pos = jnp.full((len(batch_prompts),), args.prompt_len + i,
                           jnp.int32)
            logits, cache = decode(params, cache, tok, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            outs.append(tok)
            tokens_out += len(batch_prompts)
        done += len(batch_prompts)
        gen = jnp.concatenate(outs, axis=1)
        print(f"[batch done] {len(batch_prompts)} reqs, "
              f"first gen: {np.asarray(gen[0])[:8].tolist()}", flush=True)
    dt = time.time() - t0
    print(f"served {done} requests, {tokens_out} tokens in {dt:.1f}s "
          f"({tokens_out/max(dt,1e-9):.1f} tok/s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    # LM path
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    # DiT path
    ap.add_argument("--budget", type=float, default=0.6,
                    help="base relative-compute budget for DiT requests")
    ap.add_argument("--budget-levels", default=None,
                    help="comma-separated relative-compute menu, e.g. "
                         "'0.4,0.6,1.0' (default: derived from --budget)")
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "edf", "degrade"],
                    help="serving-engine admission/step policy: arrival "
                         "order, earliest deadline first, or SLA-aware "
                         "budget degradation under load")
    ap.add_argument("--max-tokens-per-step", type=int, default=None,
                    help="token-packing budget of one engine step "
                         "(default: four full-grid CFG requests)")
    ap.add_argument("--cache-policy", default="off",
                    choices=["off", "interval", "banded", "proxy"],
                    help="cross-step activation cache refresh policy "
                         "(DESIGN.md §cache); off disables caching")
    ap.add_argument("--cache-interval", type=int, default=2,
                    help="refresh every k steps (interval policy / band "
                         "fallback); 1 is bit-identical to no cache")
    ap.add_argument("--cache-threshold", type=float, default=0.05,
                    help="proxy policy: analytic conditioning-drift "
                         "threshold triggering a refresh")
    ap.add_argument("--attn-backend", default="auto",
                    choices=["auto", "pallas", "xla-blocked", "dense"],
                    help="attention backend (DESIGN.md §attention-backend): "
                         "auto runs the segment-aware Pallas flash kernel "
                         "on packed/long token streams, dense XLA otherwise. "
                         "On CPU-only hosts the kernel executes in interpret "
                         "mode (semantics-true, wall-clock-slow) — pass "
                         "'dense' there when serving for throughput")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record per-request span tracing + device taps "
                         "and dump a Chrome-trace JSON loadable in "
                         "ui.perfetto.dev (DESIGN.md §telemetry)")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    metavar="N",
                    help="emit one structured [metrics] line every N "
                         "engine steps (0 = off); also enables taps")
    ap.add_argument("--profile", action="store_true",
                    help="compiled-cost profiling (DESIGN.md §profiling): "
                         "harvest XLA cost/memory analysis for every "
                         "compiled runner, measure per-dispatch wall, "
                         "attribute served cost per request, and print "
                         "the analytic/XLA/wall reconciliation report")
    ap.add_argument("--postmortem-dir", default=None, metavar="DIR",
                    help="enable the SLO watchdog + flight recorder: "
                         "alerts and uncaught engine exceptions dump a "
                         "post-mortem bundle (spans, engine/cache/queue "
                         "snapshot, attribution, compiled costs) here")
    ap.add_argument("--slo-p99", type=float, default=None, metavar="SEC",
                    help="p99 latency SLO for the watchdog's rolling "
                         "breach detector (default: off)")
    ap.add_argument("--mesh", default=None,
                    help="DATAxSEQ device mesh for the DiT path, e.g. 2x4. "
                         "With --replicas N (N == DATA) each replica owns "
                         "one contiguous SEQ-wide device slice and the "
                         "fleet router places requests across them; "
                         "without --replicas the legacy single-driver "
                         "fixed-slot path shards each batch over the "
                         "whole mesh")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through the fleet router with N replica "
                         "engines (repro.fleet, DESIGN.md §fleet); 1 = "
                         "single engine, no router")
    ap.add_argument("--router", default="cheapest",
                    choices=["cheapest", "affinity", "rr"],
                    help="fleet placement policy: cheapest priced "
                         "backlog, cache affinity (sticky home replica + "
                         "class sharding), or round-robin")
    ap.add_argument("--T", type=int, default=20,
                    help="DiT denoising steps per request")
    ap.add_argument("--train-T", type=int, default=1000,
                    help="diffusion schedule length the DiT was trained at")
    ap.add_argument("--solver", default="ddim",
                    choices=["ddim", "ddpm", "dpm2"])
    ap.add_argument("--cfg-scale", type=float, default=1.5)
    args = ap.parse_args()

    if args.mesh:
        # CPU smoke runs: make sure enough host devices exist BEFORE the
        # jax backend initializes.
        from repro.launch.mesh import ensure_host_devices, parse_mesh_arg
        ensure_host_devices(int(np.prod(parse_mesh_arg(args.mesh))))

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.family == "dit":
        serve_dit(cfg, args)
    else:
        serve_lm(cfg, args)


if __name__ == "__main__":
    main()
