"""Builders for the jitted step functions (train / prefill / decode / DiT).

These are the functions the dry-run lowers and the launchers execute.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.diffusion import schedule as sch
from repro.models import dit as dit_mod
from repro.models import lm
from repro.models.common import dtype_of
from repro.optim import adamw

Params = Any


def _tree_zeros_like_f32(tree: Params) -> Params:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def make_train_step(cfg: ModelConfig, tc: TrainConfig,
                    n_microbatches: int = 1,
                    trainable: Optional[Params] = None,
                    backend: str = "xla") -> Callable:
    """(params, opt_state, batch) → (params, opt_state, metrics).

    Gradient accumulation: batch leaves [B, ...] are split into
    ``n_microbatches`` chunks scanned sequentially (bounds activation
    memory; see DESIGN.md §5)."""

    def loss_fn(params, batch):
        return lm.lm_loss(params, batch, cfg, backend=backend)

    def train_step(params, opt_state, batch):
        if n_microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape((n_microbatches, x.shape[0] // n_microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def body(acc, mb):
                g_acc, l_acc = acc
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / n_microbatches,
                    g_acc, g)
                return (g_acc, l_acc + l / n_microbatches), m

            from repro.models.common import scan_or_unroll
            (grads, loss), ms = scan_or_unroll(
                body, (_tree_zeros_like_f32(params), jnp.zeros((), jnp.float32)),
                mbs, cfg.unroll)
            metrics = jax.tree.map(lambda x: x[-1], ms)
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        params, opt_state, om = adamw.adamw_update(params, grads, opt_state,
                                                   tc, trainable)
        return params, opt_state, {**metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, backend: str = "xla") -> Callable:
    def prefill_step(params, inputs):
        logits, cache = lm.prefill(params, inputs["tokens"], cfg,
                                   extra=inputs, backend=backend)
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, token, pos):
        return lm.decode_step(params, cache, token, pos, cfg)
    return decode_step


# ---------------------------------------------------------------------------
# DiT steps


def make_dit_train_step(cfg: ModelConfig, tc: TrainConfig,
                        sched: Optional[sch.DiffusionSchedule] = None,
                        mode: int = 0,
                        trainable: Optional[Params] = None) -> Callable:
    """Denoising-objective train step at a fixed patch mode. The FlexiDiT
    fine-tuning driver alternates modes across steps (different compiled
    executables), matching §4.1: 'learn to denoise using one of the
    available patch sizes'."""
    sched = sched or sch.linear_schedule(1000)

    def loss_fn(params, batch, key):
        x0 = batch["x0"].astype(dtype_of(cfg.compute_dtype))
        k_t, k_n = jax.random.split(key)
        B = x0.shape[0]
        t = jax.random.randint(k_t, (B,), 0, sched.num_steps)
        noise = jax.random.normal(k_n, x0.shape, x0.dtype)
        x_t = sch.q_sample(sched, x0, t, noise)
        out = dit_mod.dit_forward(params, x_t, t, batch.get("cond"), cfg,
                                  mode=mode)
        eps = dit_mod.eps_prediction(out, cfg)
        loss = jnp.mean(jnp.square(eps.astype(jnp.float32)
                                   - noise.astype(jnp.float32)))
        return loss, {"loss": loss}

    def train_step(params, opt_state, batch, key):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, key)
        params, opt_state, om = adamw.adamw_update(params, grads, opt_state,
                                                   tc, trainable)
        return params, opt_state, {**metrics, **om}

    return train_step


def make_dit_serve_step(cfg: ModelConfig, mode_cond: int = 0,
                        mode_uncond: Optional[int] = None,
                        cfg_scale: float = 4.0) -> Callable:
    """One guided NFE (the unit of FlexiDiT sampling): conditional at
    ``mode_cond``, guidance at ``mode_uncond`` (paper §3.4)."""
    mode_uncond = mode_cond if mode_uncond is None else mode_uncond

    def serve_step(params, x_t, t, cond, null_cond):
        from repro.core.guidance import GuidanceConfig, make_eps_fn
        kind = "uncond" if mode_cond == mode_uncond else "weak_cond"
        g = GuidanceConfig(scale=cfg_scale, mode_cond=mode_cond,
                           mode_uncond=mode_uncond, kind=kind)
        eps_fn = make_eps_fn(params, cfg, cond, null_cond, g)
        eps, logvar = eps_fn(x_t, t)
        return eps if logvar is None else (eps, logvar)

    return serve_step
