"""Deterministic synthetic data pipelines with host-sharded loading and
background prefetch.

Real deployments swap ``*_batch`` for array-record/TFDS readers; the
sharding/prefetch/straggler plumbing stays identical.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import numpy as np


class HostShardedLoader:
    """Splits the global batch across data-parallel hosts and prefetches.

    ``make_batch(step, shard_id, n_shards, rng)`` returns this host's shard.
    """

    def __init__(self, make_batch: Callable[..., Dict[str, np.ndarray]],
                 shard_id: int = 0, n_shards: int = 1, seed: int = 0,
                 prefetch: int = 2):
        self.make_batch = make_batch
        self.shard_id = shard_id
        self.n_shards = n_shards
        self.seed = seed
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, prefetch))
        self._step = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = 0
        while not self._stop.is_set():
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 65_537 + self.shard_id)
            batch = self.make_batch(step, self.shard_id, self.n_shards, rng)
            self._q.put(batch)
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


# ---------------------------------------------------------------------------
# LM synthetic corpus: a deterministic Markov-ish token stream so the loss
# has learnable structure (bigram statistics), not uniform noise.


def make_lm_batch_fn(vocab: int, seq_len: int, global_batch: int,
                     structure: int = 16):
    def make_batch(step, shard, n_shards, rng):
        b = global_batch // n_shards
        base = rng.integers(0, vocab, size=(b, seq_len + 1), dtype=np.int32)
        # inject learnable bigram structure: every token at even positions
        # determines the next token modulo `structure`.
        nxt = (base[:, :-1] * 31 + 7) % max(1, vocab // structure)
        mask = (np.arange(seq_len) % 2 == 0)[None, :]
        tok = base.copy()
        tok[:, 1:] = np.where(mask, nxt, base[:, 1:])
        return {"tokens": tok[:, :-1], "targets": tok[:, 1:]}
    return make_batch


# ---------------------------------------------------------------------------
# DiT synthetic latents: class-dependent low-frequency patterns + noise, so
# FID-proxies and weak/powerful comparisons have real signal.


def class_pattern(c: int, latent_shape: Tuple[int, int, int, int],
                  seed: int = 1234, hf_scale: float = 0.4) -> np.ndarray:
    """Class-dependent pattern = low-frequency structure + class-specific
    HIGH-frequency detail (so coarse-patch weak models genuinely cannot
    represent everything — required for the Fig. 4 / spectral claims to be
    observable at toy scale)."""
    F, H, W, C = latent_shape
    rng = np.random.default_rng(seed + c)
    low = rng.normal(size=(max(1, F // 2), max(2, H // 4), max(2, W // 4), C))
    reps = (-(-F // low.shape[0]), -(-H // low.shape[1]),
            -(-W // low.shape[2]), 1)
    up = np.kron(low, np.ones((reps[0], reps[1], reps[2], 1)))[:F, :H, :W]
    hf = rng.normal(size=(F, H, W, C))          # pixel-rate detail
    checker = ((np.arange(H)[None, :, None, None]
                + np.arange(W)[None, None, :, None]) % 2) * 2.0 - 1.0
    return (up + hf_scale * hf * checker).astype(np.float32)


def make_dit_batch_fn(latent_shape, num_classes: int, global_batch: int,
                      noise_scale: float = 0.25):
    def make_batch(step, shard, n_shards, rng):
        b = global_batch // n_shards
        cond = rng.integers(0, num_classes, size=(b,), dtype=np.int32)
        x0 = np.stack([class_pattern(int(c), latent_shape) for c in cond])
        x0 = x0 + noise_scale * rng.normal(size=x0.shape).astype(np.float32)
        return {"x0": x0, "cond": cond}
    return make_batch


def make_text_cond_batch_fn(latent_shape, text_len: int, text_dim: int,
                            global_batch: int, n_concepts: int = 32):
    """T2I synthetic pairs: the text embedding is a fixed random projection
    of the class concept that also drives the image pattern."""
    rng0 = np.random.default_rng(999)
    concept_emb = rng0.normal(size=(n_concepts, text_len, text_dim)) \
        .astype(np.float32)

    def make_batch(step, shard, n_shards, rng):
        b = global_batch // n_shards
        cid = rng.integers(0, n_concepts, size=(b,), dtype=np.int32)
        x0 = np.stack([class_pattern(int(c), latent_shape, seed=777)
                       for c in cid])
        x0 = x0 + 0.25 * rng.normal(size=x0.shape).astype(np.float32)
        return {"x0": x0, "cond": concept_emb[cid], "concept": cid}
    return make_batch
