"""Mesh-bound sequence-parallel engine (DESIGN.md §distributed).

:class:`SeqParallel` is the runtime object the pipeline threads through
``make_eps_fn`` → ``dit_forward`` → ``_mha``: it owns the mesh, the
resolved all-to-all implementation, and the token-level pad/shard/unshard
plumbing. It is built per ``(mesh fingerprint, ParallelSpec)`` at runner
compile time — sampling code only ever sees the declarative
:class:`~repro.distributed.partition.ParallelSpec` on the plan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import attention as dist_attn
from repro.distributed.partition import ParallelSpec, resolve_impl
from repro.runtime.padding import pad_to, round_up_to_multiple


def mesh_fingerprint(mesh: Optional[Mesh]) -> Optional[Tuple]:
    """Hashable identity of a mesh for compile-cache keys: axis layout plus
    the physical device assignment (a new mesh over the same devices with
    the same layout reuses executables)."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


@dataclasses.dataclass(frozen=True)
class SeqParallel:
    """A ParallelSpec bound to a mesh, ready to run inside jit."""
    mesh: Mesh
    axis: str
    impl: str                    # 'ulysses' | 'ring' (resolved)
    # attention backend for the post-all-to-all inner attend (Ulysses);
    # DESIGN.md §attention-backend. 'auto' → the segment-aware Pallas
    # flash kernel (padding segments become skipped blocks, not masks).
    attn_backend: str = "auto"

    @classmethod
    def create(cls, mesh: Optional[Mesh], spec: ParallelSpec,
               cfg: ModelConfig, attn_backend: str = "auto") -> "SeqParallel":
        if mesh is None:
            raise ValueError("plan.parallel needs a device mesh; construct "
                             "FlexiPipeline(..., mesh=...) or set_mesh()")
        if spec.axis not in mesh.axis_names:
            raise ValueError(f"mesh has no '{spec.axis}' axis "
                             f"(axes: {mesh.axis_names})")
        return cls(mesh=mesh, axis=spec.axis,
                   impl=resolve_impl(cfg, spec, mesh.shape[spec.axis]),
                   attn_backend=attn_backend)

    @property
    def sp(self) -> int:
        return self.mesh.shape[self.axis]

    # ------------------------------------------------------------------
    # Token plumbing (inside jit)

    def pad_and_shard(self, tok: jax.Array,
                      segment_ids: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """Pad [B, N, d] tokens to a multiple of sp and pin them to the
        engine's inter-layer layout. Padding tokens get segment id -1 so
        they never contribute as attention keys."""
        B, N = tok.shape[:2]
        target = round_up_to_multiple(N, self.sp)
        if target != N:
            tok = pad_to(tok, target, axis=1)
            if segment_ids is None:
                segment_ids = jnp.zeros((B, N), jnp.int32)
            segment_ids = pad_to(segment_ids, target, axis=1, value=-1)
        tok = jax.lax.with_sharding_constraint(
            tok, NamedSharding(self.mesh, self._interlayer_spec(tok.ndim)))
        return tok, segment_ids

    def _interlayer_spec(self, ndim: int) -> P:
        """Layout activations keep BETWEEN shard_map calls. jax 0.4.x GSPMD
        miscompiles resharding jit-internal intermediates onto the sequence
        axis, so outside the collectives we keep activations replicated
        (batch sharding across data axes is reintroduced by the harness
        once that bug is gone — see ROADMAP 'Open items')."""
        return P(*(None,) * ndim)

    def unshard(self, tok: jax.Array, n_tokens: int) -> jax.Array:
        """Drop padding rows after the blocks (before de-embedding)."""
        return tok[:, :n_tokens]

    def attend(self, q: jax.Array, k: jax.Array, v: jax.Array,
               segment_ids: Optional[jax.Array] = None) -> jax.Array:
        # Pin the operands to a replicated layout before the shard_map
        # boundary: jax 0.4.x GSPMD miscompiles the direct reshard of
        # jit-internal intermediates into the (data, seq) layout (verified
        # against the dense path — values upstream of the boundary change).
        # Entering from the replicated layout is correct, and the slice to
        # per-shard blocks is local.
        repl = NamedSharding(self.mesh, P())
        q = jax.lax.with_sharding_constraint(q, repl)
        k = jax.lax.with_sharding_constraint(k, repl)
        v = jax.lax.with_sharding_constraint(v, repl)
        if segment_ids is not None:
            segment_ids = jax.lax.with_sharding_constraint(segment_ids, repl)
        fn = dist_attn.ATTN_FNS[self.impl]
        out = fn(q, k, v, mesh=self.mesh, axis=self.axis,
                 segment_ids=segment_ids, attn_backend=self.attn_backend)
        # ... and pin the collective's output the same way so downstream
        # consumers never see a seq-sharded intermediate either.
        return jax.lax.with_sharding_constraint(out, repl)
