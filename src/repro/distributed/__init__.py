"""Multi-device distributed inference engine (DESIGN.md §distributed).

Sequence-parallel FlexiDiT sampling: ``partition`` owns the static
sharding/cost arithmetic (per-mode token shards, phase-boundary re-shards,
padding FLOPs, collective bytes), ``attention`` the shard_map collectives
(Ulysses all-to-all + ring fallback), and ``engine`` the mesh-bound
runtime the pipeline threads through the model. User code enables it by
putting a :class:`ParallelSpec` on a ``SamplingPlan`` and giving
``FlexiPipeline`` a mesh.
"""
from repro.distributed.attention import ring_attention, ulysses_attention
from repro.distributed.engine import SeqParallel, mesh_fingerprint
from repro.distributed.partition import (ModePartition, ParallelSpec,
                                         PartitionPlan, mode_partition,
                                         padded_tokens, plan_partition,
                                         resolve_impl)

__all__ = [
    "ModePartition", "ParallelSpec", "PartitionPlan", "SeqParallel",
    "mesh_fingerprint", "mode_partition", "padded_tokens", "plan_partition",
    "resolve_impl", "ring_attention", "ulysses_attention",
]
