"""Sequence-parallel attention collectives (DESIGN.md §distributed).

Two shard_map implementations over a named sequence axis, both taking
globally-shaped ``q, k, v: [B, N, H, hd]`` whose sequence dim is sharded
over ``axis`` and returning the attention output with the same sharding:

* :func:`ulysses_attention` — DeepSpeed-Ulysses style: ``all_to_all``
  turns the sequence sharding into a head sharding (every shard sees the
  full sequence for H/sp heads), runs the ordinary inner attention —
  ``models.attention.blocked_gqa_attend`` for long sequences, the dense
  GQA path otherwise — then all_to_alls back. Requires H % sp == 0.

* :func:`ring_attention` — K/V chunks rotate around the axis via
  ``ppermute`` while a flash-style running softmax (max / numerator /
  denominator carried in f32) accumulates the output. No head-count
  constraint; this is the fallback for meshes where heads don't divide.

Padding tokens (the engine pads N to a multiple of sp) are masked via
``segment_ids``: real tokens carry segment >= 0, padding carries -1 and
never contributes as a key. Padded query rows produce garbage that the
caller slices off.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import AttnConfig
from repro.models import attention as attn_mod
from repro.runtime.sharding import batch_spec


def _specs(mesh: Mesh, axis: str, batch: int):
    b = batch_spec(batch, mesh)[0]     # the runtime's one batch-axis rule
    return P(b, axis, None, None), P(b, axis)


def _inner_cfg(heads: int, head_dim: int) -> AttnConfig:
    return AttnConfig(num_heads=heads, num_kv_heads=heads,
                      head_dim=head_dim, use_rope=False)


def _dense_attend(q, k, v, seg, cfg: AttnConfig, attn_backend: str = "auto"):
    """Post-all-to-all inner attention on one shard's heads (every shard
    sees the FULL sequence for H/sp heads). The backend selects the
    implementation: 'auto'/'pallas' run the segment-aware Pallas flash
    kernel — padding (segment -1) kv blocks and cross-segment tiles of a
    packed stream are skipped, not computed-then-masked."""
    B, S = q.shape[:2]
    resolved = attn_mod.resolve_backend(attn_backend, n_tokens=S,
                                        segmented=seg is not None)
    if resolved == "pallas":
        from repro.kernels.attention import ops as attn_ops
        return attn_ops.flash_attention(q, k, v, causal=False,
                                        softcap=cfg.logit_softcap,
                                        segment_ids=seg)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if resolved == "xla-blocked":
        return attn_mod.blocked_gqa_attend(q, k, v, positions=pos,
                                           causal=False, window=0, cfg=cfg,
                                           segment_ids=seg)
    bias = attn_mod.make_attention_bias(pos, pos, causal=False, window=0,
                                        q_segment=seg, k_segment=seg)
    return attn_mod.gqa_attend(q, k, v, bias, cfg)


def ulysses_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      mesh: Mesh, axis: str,
                      segment_ids: Optional[jax.Array] = None,
                      attn_backend: str = "auto") -> jax.Array:
    """All-to-all attention: sequence-sharded in, sequence-sharded out."""
    B, N, H, hd = q.shape
    sp = mesh.shape[axis]
    if H % sp != 0:
        raise ValueError(f"ulysses needs heads ({H}) % axis size ({sp}) == 0")
    if N % sp != 0:
        raise ValueError(f"sequence ({N}) must be padded to the axis size "
                         f"({sp}) before ulysses_attention")
    qspec, sspec = _specs(mesh, axis, B)
    cfg = _inner_cfg(H // sp, hd)
    if segment_ids is None:
        segment_ids = jnp.zeros((B, N), jnp.int32)

    def inner(q, k, v, seg):
        # [b, N/sp, H, hd] → [b, N, H/sp, hd]: heads gathered, seq scattered
        qf = jax.lax.all_to_all(q, axis, split_axis=2, concat_axis=1,
                                tiled=True)
        kf = jax.lax.all_to_all(k, axis, split_axis=2, concat_axis=1,
                                tiled=True)
        vf = jax.lax.all_to_all(v, axis, split_axis=2, concat_axis=1,
                                tiled=True)
        segf = jax.lax.all_gather(seg, axis, axis=1, tiled=True)
        o = _dense_attend(qf, kf, vf, segf, cfg, attn_backend=attn_backend)
        return jax.lax.all_to_all(o, axis, split_axis=1, concat_axis=2,
                                  tiled=True)

    return shard_map(inner, mesh=mesh,
                     in_specs=(qspec, qspec, qspec, sspec),
                     out_specs=qspec, check_rep=False)(q, k, v, segment_ids)


def ring_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   mesh: Mesh, axis: str,
                   segment_ids: Optional[jax.Array] = None,
                   attn_backend: str = "auto") -> jax.Array:
    """Ring attention: local queries, K/V chunks rotating via ppermute with
    a streaming-softmax accumulator. Works for any head count.
    ``attn_backend`` is accepted for interface parity with
    :func:`ulysses_attention` but unused: the rotating accumulator IS the
    flash-style inner loop (one chunk-sized score tile at a time)."""
    del attn_backend
    B, N, H, hd = q.shape
    sp = mesh.shape[axis]
    if N % sp != 0:
        raise ValueError(f"sequence ({N}) must be padded to the axis size "
                         f"({sp}) before ring_attention")
    qspec, sspec = _specs(mesh, axis, B)
    if segment_ids is None:
        segment_ids = jnp.zeros((B, N), jnp.int32)
    perm = [(j, (j - 1) % sp) for j in range(sp)]
    scale = 1.0 / np.sqrt(hd)

    def inner(q, k, v, seg):
        seg_q = seg

        def accumulate(acc, k_c, v_c, seg_c):
            m, num, den = acc
            s = jnp.einsum("bqhd,bkhd->bqhk", q, k_c,
                           preferred_element_type=jnp.float32) * scale
            from repro.kernels.attention import mask as mask_mod
            mask = mask_mod.segment_allowed(seg_q, seg_c)
            s = jnp.where(mask[:, :, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(mask[:, :, None, :],
                          jnp.exp(s - m_safe[..., None]), 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            num = (num * corr[..., None]
                   + jnp.einsum("bqhk,bkhd->bqhd", p,
                                v_c.astype(jnp.float32)))
            den = den * corr + jnp.sum(p, axis=-1)
            return m_new, num, den

        # local chunk first, then rotate-and-accumulate (sp-1) hops — no
        # dead final rotation, so traffic matches the analytic ledger
        # (partition.ModePartition.collective_bytes_per_nfe)
        acc = (jnp.full(q.shape[:2] + (H,), -jnp.inf, jnp.float32),
               jnp.zeros(q.shape, jnp.float32),
               jnp.zeros(q.shape[:2] + (H,), jnp.float32))
        acc = accumulate(acc, k, v, seg_q)

        def step(carry, _):
            k_c, v_c, seg_c, acc = carry
            k_c = jax.lax.ppermute(k_c, axis, perm)
            v_c = jax.lax.ppermute(v_c, axis, perm)
            seg_c = jax.lax.ppermute(seg_c, axis, perm)
            return (k_c, v_c, seg_c, accumulate(acc, k_c, v_c, seg_c)), None

        (_, _, _, (_, num, den)), _ = jax.lax.scan(
            step, (k, v, seg_q, acc), None, length=sp - 1)
        out = num / jnp.maximum(den, 1e-30)[..., None]
        return out.astype(q.dtype)

    return shard_map(inner, mesh=mesh,
                     in_specs=(qspec, qspec, qspec, sspec),
                     out_specs=qspec, check_rep=False)(q, k, v, segment_ids)


ATTN_FNS = {"ulysses": ulysses_attention, "ring": ring_attention}
