"""Sequence-parallel partitioning for FlexiDiT sampling (DESIGN.md
§distributed).

FlexiDiT's twist on parallel DiT inference (xDiT / PipeFusion style
engines): the token count *changes at phase boundaries* when the model
drops to a weak patch size. This module owns the static arithmetic of
that: per-mode token shardings (pad-to-divisible over the sequence axis),
the re-shard points between phases, and the analytic cost extensions —
padding FLOPs and collective bytes — layered on top of
``core.scheduler``'s per-NFE accounting.

Nothing here touches devices; the runtime halves live in
``distributed.engine`` (mesh binding) and ``distributed.attention``
(shard_map collectives).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.scheduler import (FlexiSchedule, dit_block_flops,
                                  dit_nfe_flops)
from repro.models import dit as dit_mod
from repro.runtime.padding import round_up_to_multiple

ATTN_IMPLS = ("auto", "ulysses", "ring")


@dataclasses.dataclass(frozen=True)
class ParallelSpec:
    """Declarative sequence-parallel request attached to a
    :class:`~repro.pipeline.plan.SamplingPlan`.

    ``axis`` names the mesh axis the sequence is scattered over; ``attn``
    picks the all-to-all implementation: ``'ulysses'`` (heads gathered,
    sequence scattered — requires heads % axis size == 0), ``'ring'``
    (K/V chunks rotate, any head count), or ``'auto'`` (ulysses when
    heads divide, ring otherwise). The spec is mesh-free and hashable so
    plans stay frozen; the mesh is bound by the pipeline at sample time.
    """
    axis: str = "seq"
    attn: str = "auto"

    def __post_init__(self):
        if not self.axis or not isinstance(self.axis, str):
            raise ValueError(f"parallel axis must be a non-empty mesh axis "
                             f"name, got {self.axis!r}")
        if self.attn not in ATTN_IMPLS:
            raise ValueError(f"unknown parallel attn {self.attn!r}; "
                             f"known: {ATTN_IMPLS}")


def padded_tokens(n_tokens: int, sp: int) -> int:
    """Smallest multiple of ``sp`` holding ``n_tokens`` tokens."""
    return round_up_to_multiple(n_tokens, sp)


@dataclasses.dataclass(frozen=True)
class ModePartition:
    """How one patch mode's token sequence lands on ``sp`` shards."""
    mode: int
    sp: int
    tokens: int                  # real tokens N for this mode
    tokens_padded: int           # N padded up to a multiple of sp
    impl: str                    # 'ulysses' | 'ring' (resolved, not 'auto')

    @property
    def pad(self) -> int:
        return self.tokens_padded - self.tokens

    @property
    def shard_tokens(self) -> int:
        return self.tokens_padded // self.sp

    def pad_flops_per_nfe(self, cfg: ModelConfig) -> float:
        """Extra block FLOPs one NFE spends on padding tokens (batch 1).

        Padding is applied at the token level after embedding, so only the
        transformer blocks see the padded length."""
        if self.pad == 0:
            return 0.0
        return (dit_block_flops(cfg, self.tokens_padded)
                - dit_block_flops(cfg, self.tokens))

    def collective_bytes_per_nfe(self, cfg: ModelConfig) -> float:
        """Bytes crossing devices for one NFE (batch 1), summed over all
        shards and layers.

        Ulysses: 4 all-to-alls per attention (q, k, v in; output back),
        each redistributing the full [N_pad, d] activation — every shard
        keeps 1/sp of what it holds, so (sp-1)/sp of the tensor moves.

        Ring: (sp-1) rotation steps per attention, each moving the local
        K and V chunks [N_pad/sp, d] from every shard.
        """
        if self.sp <= 1:
            return 0.0
        d, L = cfg.d_model, cfg.num_layers
        elt = _dtype_bytes(cfg.compute_dtype)
        if self.impl == "ulysses":
            per_a2a = self.tokens_padded * d * elt * (self.sp - 1) / self.sp
            return float(L * 4 * per_a2a)
        per_hop = self.shard_tokens * d * elt * self.sp   # all shards send
        return float(L * 2 * (self.sp - 1) * per_hop)


def _dtype_bytes(name: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2}.get(name, 4)


def resolve_impl(cfg: ModelConfig, spec: ParallelSpec, sp: int) -> str:
    """Pick the concrete all-to-all implementation for ``sp`` shards."""
    divides = cfg.attn.num_heads % sp == 0
    if spec.attn == "ulysses" and not divides:
        raise ValueError(
            f"ulysses attention needs num_heads ({cfg.attn.num_heads}) "
            f"divisible by the '{spec.axis}' axis size {sp}; use "
            f"attn='ring' or 'auto'")
    if spec.attn == "auto":
        return "ulysses" if divides else "ring"
    return spec.attn


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Full static sharding story for one sampling schedule: one
    :class:`ModePartition` per phase plus the re-shard boundaries."""
    phases: Tuple[Tuple[ModePartition, int], ...]   # (partition, n_steps)
    sp: int

    @property
    def reshard_boundaries(self) -> Tuple[int, ...]:
        """Step indices (into the flat ladder) where the token count
        changes and the sequence must be re-scattered."""
        out: List[int] = []
        step = 0
        for i, (part, n) in enumerate(self.phases):
            step += n
            if i + 1 < len(self.phases) and n:
                nxt = self.phases[i + 1][0]
                if nxt.tokens != part.tokens:
                    out.append(step)
        return tuple(out)

    def pad_flops(self, cfg: ModelConfig, *, cfg_scale_active: bool = True
                  ) -> float:
        mult = 2.0 if cfg_scale_active else 1.0
        return mult * sum(n * p.pad_flops_per_nfe(cfg)
                          for p, n in self.phases)

    def collective_bytes(self, cfg: ModelConfig, *,
                         cfg_scale_active: bool = True) -> float:
        """Total collective traffic for one full sample (batch 1). CFG
        doubles the effective batch of every NFE, hence the bytes."""
        mult = 2.0 if cfg_scale_active else 1.0
        return mult * sum(n * p.collective_bytes_per_nfe(cfg)
                          for p, n in self.phases)

    def parallel_efficiency(self, cfg: ModelConfig) -> float:
        """Useful FLOPs / (useful + padding) FLOPs — 1.0 means no waste."""
        useful = sum(n * dit_nfe_flops(cfg, p.mode) for p, n in self.phases)
        padded = useful + sum(n * p.pad_flops_per_nfe(cfg)
                              for p, n in self.phases)
        return useful / padded if padded else 1.0


def mode_partition(cfg: ModelConfig, mode: int, sp: int,
                   spec: Optional[ParallelSpec] = None) -> ModePartition:
    spec = spec or ParallelSpec()
    n = dit_mod.tokens_for_mode(cfg, mode)
    return ModePartition(mode=mode, sp=sp, tokens=n,
                         tokens_padded=padded_tokens(n, sp),
                         impl=resolve_impl(cfg, spec, sp))


def plan_partition(cfg: ModelConfig, schedule: FlexiSchedule, sp: int,
                   spec: Optional[ParallelSpec] = None) -> PartitionPlan:
    """Static sharding plan for a resolved :class:`FlexiSchedule`."""
    if sp < 1:
        raise ValueError(f"sp must be >= 1, got {sp}")
    parts = tuple((mode_partition(cfg, mode, sp, spec), n)
                  for mode, n in schedule.phases)
    return PartitionPlan(phases=parts, sp=sp)
